//! The stage-parallel convolution engine — one execution pipeline behind
//! all three transformed-convolution methods (Winograd, Regular-FFT,
//! Gauss-FFT).
//!
//! A [`LayerPlan`] is built **once** per (layer shape, algorithm): it
//! caches the transformed kernel tensor `V[P][K][C]` and owns grow-only
//! scratch arenas plus per-worker codelet state, so serving repeated
//! requests never re-transforms weights and never allocates on the hot
//! path (arena capacity is reached after the first batch).
//!
//! Each of the three stages is executed as one static fork-join over the
//! shared [`ThreadPool`] (paper §3, after Zlateski & Seung), with
//! equal-FLOP partitions:
//!
//! * **input transform** — sharded over the global tile index
//!   `(b, c, tile)`; every tile costs the same FLOPs, so `even_ranges`
//!   is the equal-FLOP split.  Tile granularity means batches smaller
//!   than the worker count still use every core (intra-image sharding).
//! * **element-wise stage** — sharded over the `P` transform elements;
//!   each element's `(K x C) @ (C x BN)` GEMM is independent, so shards
//!   write disjoint contiguous `&mut` panels of `Z` with no
//!   synchronization.
//! * **inverse transform** — sharded over global *tile rows*
//!   `(b, k, tile_row)`; a contiguous run of tile rows maps to a
//!   contiguous pixel range of the output tensor, so each worker gets a
//!   disjoint `&mut` output slice proven safe by the borrow checker.
//!
//! The input-transform stage writes `U[P][C][BN]` planes whose per-worker
//! regions are disjoint but *strided* (each worker owns a `(b, c)`-tile
//! run across all P planes), which no safe split can express — that one
//! stage writes through a [`SharedSlice`] whose disjointness argument is
//! documented at the call site.

use super::batch_wino::BatchSandwich;
use super::fft_conv::FftVariant;
use super::gemm::{cgemm_acc, gauss_gemm_acc, gemm_acc, GaussScratch};
use super::tensor::Tensor4;
use super::tiles::TileGrid;
use super::ConvAlgorithm;
use crate::fft::batch_dft::BatchDft;
use crate::util::threadpool::{even_ranges, ThreadPool};
use crate::winograd::matrices::winograd_matrices_f32;
use std::marker::PhantomData;
use std::ops::Range;

/// Tiles transformed per batched-codelet invocation (amortizes the
/// transform-matrix panels across the register-blocked GEMM).
const NB: usize = 32;

/// FNV-1a over the weight tensor's bit pattern — the cheap identity check
/// plan caches use to decide whether a cached kernel transform is stale.
pub fn weights_fingerprint(w: &Tensor4) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &s in &w.shape {
        h ^= s as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for &v in &w.data {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Shared mutable view over an `f32` buffer for stage shards whose
/// disjoint write sets are strided (not expressible as sub-slices).
///
/// Safety contract: every index is written by at most one worker of the
/// fork-join, and the buffer is not read until the join.  Each `set` call
/// site documents why its index set is disjoint across workers.
struct SharedSlice<'a> {
    ptr: *mut f32,
    len: usize,
    _life: PhantomData<&'a mut [f32]>,
}

unsafe impl Send for SharedSlice<'_> {}
unsafe impl Sync for SharedSlice<'_> {}

impl<'a> SharedSlice<'a> {
    fn new(s: &'a mut [f32]) -> SharedSlice<'a> {
        SharedSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            _life: PhantomData,
        }
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other worker may read or write index `i` during this fork-join.
    #[inline]
    unsafe fn set(&self, i: usize, v: f32) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

/// Run `f(i, part)` for every part — on the pool's static fork-join when a
/// pool is given, inline on the caller's thread otherwise (the serial path
/// used by the one-shot wrappers).
fn execute<T, F>(pool: Option<&ThreadPool>, parts: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Send + Sync,
{
    match pool {
        Some(p) => p.run_parts(parts, f),
        None => {
            for (i, part) in parts.into_iter().enumerate() {
                f(i, part);
            }
        }
    }
}

/// Split `buf` into per-range sub-slices of `unit` elements per item.
/// Ranges must be contiguous and tile `buf` exactly (as `even_ranges`
/// produces).  Shared with the scheduler's Direct/Im2col partitions.
pub(crate) fn split_units<'a>(
    buf: &'a mut [f32],
    ranges: &[Range<usize>],
    unit: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    for r in ranges {
        let take = (r.end - r.start) * unit;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        out.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty());
    out
}

/// The per-worker transform codelets (each worker owns its own scratch).
enum Codelets {
    Winograd {
        input: BatchSandwich,
        output: BatchSandwich,
    },
    Fft(BatchDft),
}

/// Per-worker state: codelets plus gather/transform/scatter buffers, all
/// allocated at plan build and reused across every batch.
struct WorkerState {
    codelets: Codelets,
    /// gathered input tiles, NB x t x t
    xb: Vec<f32>,
    /// transform staging (re), NB x P — also the inverse-gather buffer
    tre: Vec<f32>,
    /// transform staging (im), NB x P (FFT only; empty for Winograd)
    tim: Vec<f32>,
    /// inverse output tiles, NB x m x m
    ob: Vec<f32>,
    gauss: GaussScratch,
}

impl WorkerState {
    fn new(codelets: Codelets, t: usize, p: usize, m: usize, is_fft: bool) -> WorkerState {
        WorkerState {
            codelets,
            xb: vec![0.0; NB * t * t],
            tre: vec![0.0; NB * p],
            tim: if is_fft { vec![0.0; NB * p] } else { Vec::new() },
            ob: vec![0.0; NB * m * m],
            gauss: GaussScratch::default(),
        }
    }
}

/// A reusable, stage-parallel execution plan for one convolution layer.
pub struct LayerPlan {
    pub algo: ConvAlgorithm,
    /// input channels
    pub c: usize,
    /// output channels
    pub k: usize,
    /// input spatial size
    pub h: usize,
    pub w: usize,
    /// kernel size
    pub r: usize,
    /// output tile size
    pub m: usize,
    /// transform tile size t = m + r - 1
    pub t: usize,
    /// fingerprint of the weights the cached kernel transform belongs to
    pub weights_fp: u64,
    /// transform elements: t*t (Winograd) or th*t (FFT half spectrum)
    p: usize,
    variant: Option<FftVariant>,
    grid: TileGrid,
    // transformed kernel V[P][K][C], built once at plan construction
    vr: Vec<f32>,
    vi: Vec<f32>,
    vd: Vec<f32>,
    vs: Vec<f32>,
    // grow-only hot-path arenas (U[P][C][BN], Z[P][K][BN] planes)
    ur: Vec<f32>,
    ui: Vec<f32>,
    us: Vec<f32>,
    zr: Vec<f32>,
    zi: Vec<f32>,
    workers: Vec<WorkerState>,
}

impl LayerPlan {
    /// Build a plan: constructs per-worker codelets and transforms the
    /// kernel once.  `h`/`w` are the input spatial dims the plan serves
    /// (the batch size may vary call to call).
    pub fn new(
        algo: ConvAlgorithm,
        weights: &Tensor4,
        h: usize,
        w: usize,
        nworkers: usize,
    ) -> LayerPlan {
        let m = algo.tile_m().expect("LayerPlan requires a tiled algorithm");
        let [k, c, r, r2] = weights.shape;
        assert_eq!(r, r2, "non-square kernel");
        let variant = match algo {
            ConvAlgorithm::Winograd { .. } => None,
            ConvAlgorithm::RegularFft { .. } => Some(FftVariant::Regular),
            ConvAlgorithm::GaussFft { .. } => Some(FftVariant::Gauss),
            _ => unreachable!("tile_m() returned Some for a non-tiled algorithm"),
        };
        let grid = TileGrid::new(h, w, m, r);
        let t = m + r - 1;
        let nworkers = nworkers.max(1);
        let gauss = variant == Some(FftVariant::Gauss);

        let (p, workers, vr, vi, vd, vs) = match variant {
            None => {
                let (at, g, bt) = winograd_matrices_f32(m, r);
                let p = t * t;
                let mut workers = Vec::with_capacity(nworkers);
                for _ in 0..nworkers {
                    workers.push(WorkerState::new(
                        Codelets::Winograd {
                            input: BatchSandwich::new(&bt, t, t),
                            output: BatchSandwich::new(&at, m, t),
                        },
                        t,
                        p,
                        m,
                        false,
                    ));
                }
                let mut kernel_tf = BatchSandwich::new(&g, t, r);
                let vr = wino_kernel_transform(weights, &mut kernel_tf, p);
                (p, workers, vr, Vec::new(), Vec::new(), Vec::new())
            }
            Some(_) => {
                let tf = BatchDft::new(m, r);
                let p = tf.th * tf.t;
                let mut workers = Vec::with_capacity(nworkers);
                for _ in 0..nworkers {
                    workers.push(WorkerState::new(Codelets::Fft(tf.clone()), t, p, m, true));
                }
                let mut kernel_tf = tf;
                let (vr, vi, vd, vs) = fft_kernel_transform(weights, &mut kernel_tf, p, gauss);
                (p, workers, vr, vi, vd, vs)
            }
        };

        LayerPlan {
            algo,
            c,
            k,
            h,
            w,
            r,
            m,
            t,
            weights_fp: weights_fingerprint(weights),
            p,
            variant,
            grid,
            vr,
            vi,
            vd,
            vs,
            ur: Vec::new(),
            ui: Vec::new(),
            us: Vec::new(),
            zr: Vec::new(),
            zi: Vec::new(),
            workers,
        }
    }

    /// Shape of the output for a batch of `b` images.
    pub fn output_shape(&self, b: usize) -> [usize; 4] {
        [b, self.k, self.grid.oh, self.grid.ow]
    }

    /// Does this plan serve (algo, input shape, these weights)?
    pub fn matches(&self, algo: ConvAlgorithm, x: &Tensor4, weights_fp: u64) -> bool {
        self.algo == algo
            && x.shape[1] == self.c
            && x.shape[2] == self.h
            && x.shape[3] == self.w
            && self.weights_fp == weights_fp
    }

    /// Arena identity stamp (pointers + lengths): unchanged across two
    /// same-shape runs ⇔ the hot path did not allocate.
    pub fn arena_stamp(&self) -> (usize, usize, usize, usize) {
        (
            self.ur.as_ptr() as usize,
            self.zr.as_ptr() as usize,
            self.ur.len(),
            self.zr.len(),
        )
    }

    /// Convenience wrapper over [`LayerPlan::run_into`].
    pub fn run(&mut self, x: &Tensor4, pool: Option<&ThreadPool>) -> Tensor4 {
        let mut out = Tensor4::zeros(self.output_shape(x.shape[0]));
        self.run_into(x, &mut out, pool);
        out
    }

    /// Execute the three-stage pipeline over `x`, writing into `out`.
    ///
    /// With `Some(pool)`, every stage forks across the pool's workers with
    /// statically precomputed equal-FLOP shards; with `None` the stages run
    /// serially on the caller's thread (identical numerics either way —
    /// shard boundaries never change any per-tile or per-GEMM arithmetic).
    pub fn run_into(&mut self, x: &Tensor4, out: &mut Tensor4, pool: Option<&ThreadPool>) {
        let [b, c, h, w] = x.shape;
        assert_eq!(c, self.c, "channel mismatch");
        assert_eq!((h, w), (self.h, self.w), "input spatial shape mismatch");
        assert_eq!(out.shape, self.output_shape(b), "output shape mismatch");
        let grid = self.grid;
        let (k, m, t, p) = (self.k, self.m, self.t, self.p);
        let n = grid.tiles();
        let bn = b * n;
        let is_fft = self.variant.is_some();
        let gauss = self.variant == Some(FftVariant::Gauss);
        let nw = self.workers.len();

        // grow-only arenas: no allocation once the high-water batch is seen
        let need_u = p * c * bn;
        let need_z = p * k * bn;
        if self.ur.len() < need_u {
            self.ur.resize(need_u, 0.0);
        }
        if self.zr.len() < need_z {
            self.zr.resize(need_z, 0.0);
        }
        if is_fft {
            if self.ui.len() < need_u {
                self.ui.resize(need_u, 0.0);
            }
            if self.zi.len() < need_z {
                self.zi.resize(need_z, 0.0);
            }
        }
        if gauss && self.us.len() < need_u {
            self.us.resize(need_u, 0.0);
        }

        // ---- stage 1: input transform, sharded over (b, c, tile) ----
        {
            let shards = even_ranges(b * c * n, nw);
            let u_re = SharedSlice::new(&mut self.ur[..need_u]);
            let u_im = if is_fft {
                Some(SharedSlice::new(&mut self.ui[..need_u]))
            } else {
                None
            };
            let u_s = if gauss {
                Some(SharedSlice::new(&mut self.us[..need_u]))
            } else {
                None
            };
            let parts: Vec<(Range<usize>, &mut WorkerState)> =
                shards.into_iter().zip(self.workers.iter_mut()).collect();
            execute(pool, parts, |_wi, (range, ws)| {
                let mut g = range.start;
                while g < range.end {
                    let bc = g / n;
                    let ni0 = g % n;
                    let (bi, ci) = (bc / c, bc % c);
                    let cnt = NB.min(n - ni0).min(range.end - g);
                    let plane = x.plane(bi, ci);
                    for s in 0..cnt {
                        let ni = ni0 + s;
                        let (ti, tj) = (ni / grid.nw, ni % grid.nw);
                        grid.gather(plane, ti, tj, &mut ws.xb[s * t * t..(s + 1) * t * t]);
                    }
                    match &mut ws.codelets {
                        Codelets::Winograd { input, .. } => {
                            input.apply(&ws.xb[..cnt * t * t], cnt, &mut ws.tre[..cnt * p]);
                        }
                        Codelets::Fft(tf) => {
                            tf.forward(
                                &ws.xb[..cnt * t * t],
                                cnt,
                                t,
                                &mut ws.tre[..cnt * p],
                                &mut ws.tim[..cnt * p],
                            );
                        }
                    }
                    // Disjointness: workers own disjoint (bi, ci, ni)
                    // ranges, and U index (pp*c + ci)*bn + bi*n + ni is
                    // injective in (ci, bi, ni) for every pp.
                    let base = bi * n + ni0;
                    for pp in 0..p {
                        let off = (pp * c + ci) * bn + base;
                        for s in 0..cnt {
                            let re = ws.tre[s * p + pp];
                            unsafe { u_re.set(off + s, re) };
                            if let Some(u_im) = &u_im {
                                let im = ws.tim[s * p + pp];
                                unsafe { u_im.set(off + s, im) };
                                if let Some(u_s) = &u_s {
                                    unsafe { u_s.set(off + s, re + im) };
                                }
                            }
                        }
                    }
                    g += cnt;
                }
            });
        }

        // ---- stage 2: element-wise GEMMs, sharded over the P elements ----
        {
            let shards = even_ranges(p, nw);
            let zr_parts = split_units(&mut self.zr[..need_z], &shards, k * bn);
            let zi_parts: Vec<&mut [f32]> = if is_fft {
                split_units(&mut self.zi[..need_z], &shards, k * bn)
            } else {
                // Winograd has no imaginary plane: hand out empty slices
                (0..nw).map(|_| Default::default()).collect()
            };
            let ur = &self.ur[..need_u];
            let ui = &self.ui[..if is_fft { need_u } else { 0 }];
            let us = &self.us[..if gauss { need_u } else { 0 }];
            let (vr, vi, vd, vs) = (&self.vr, &self.vi, &self.vd, &self.vs);
            let mut parts = Vec::with_capacity(nw);
            for (((range, zr_s), zi_s), ws) in shards
                .iter()
                .cloned()
                .zip(zr_parts)
                .zip(zi_parts)
                .zip(self.workers.iter_mut())
            {
                parts.push((range, zr_s, zi_s, ws));
            }
            execute(pool, parts, |_wi, (range, zr_s, zi_s, ws)| {
                for (idx, pp) in range.enumerate() {
                    let z0 = idx * k * bn;
                    let zr_p = &mut zr_s[z0..z0 + k * bn];
                    zr_p.fill(0.0);
                    let ur_p = &ur[pp * c * bn..(pp + 1) * c * bn];
                    let vr_p = &vr[pp * k * c..(pp + 1) * k * c];
                    if !is_fft {
                        // Z_p (K x BN) = V_p (K x C) @ U_p (C x BN)
                        gemm_acc(zr_p, vr_p, ur_p, k, c, bn);
                        continue;
                    }
                    let zi_p = &mut zi_s[z0..z0 + k * bn];
                    zi_p.fill(0.0);
                    let ui_p = &ui[pp * c * bn..(pp + 1) * c * bn];
                    let vi_p = &vi[pp * k * c..(pp + 1) * k * c];
                    if gauss {
                        // transposed Gauss: t1 = Vr@Us, t2 = Vd@Ur, t3 = Vs@Ui
                        // (gauss_gemm_acc computes t1 = arg_us@arg_vr etc., so
                        // the kernel-side planes go in the "u" slots and vice
                        // versa — identical to the pre-engine layer code)
                        gauss_gemm_acc(
                            zr_p,
                            zi_p,
                            &vd[pp * k * c..(pp + 1) * k * c], // arg ur -> t2 lhs
                            &vs[pp * k * c..(pp + 1) * k * c], // arg ui -> t3 lhs
                            vr_p,                              // arg us -> t1 lhs
                            &us[pp * c * bn..(pp + 1) * c * bn], // arg vr -> t1 rhs
                            ur_p,                              // arg vd -> t2 rhs
                            ui_p,                              // arg vs -> t3 rhs
                            k,
                            c,
                            bn,
                            &mut ws.gauss,
                        );
                    } else {
                        cgemm_acc(zr_p, zi_p, vr_p, vi_p, ur_p, ui_p, k, c, bn);
                    }
                }
            });
        }

        // ---- stage 3: pruned inverse + scatter, sharded over (b, k, tile row) ----
        {
            let nh = grid.nh;
            let plane_len = grid.oh * grid.ow;
            let shards = even_ranges(b * k * nh, nw);
            // a contiguous run of global tile rows is a contiguous pixel
            // range of out.data, so the split below is a safe partition
            let addr = |gr: usize| -> usize {
                let (q, row) = (gr / nh, gr % nh);
                q * plane_len + (row * m).min(grid.oh) * grid.ow
            };
            let mut parts = Vec::with_capacity(nw);
            {
                let mut rest: &mut [f32] = &mut out.data[..];
                let mut pos = 0usize;
                for (range, ws) in shards.iter().cloned().zip(self.workers.iter_mut()) {
                    let end = addr(range.end);
                    let (head, tail) = std::mem::take(&mut rest).split_at_mut(end - pos);
                    parts.push((range, head, ws));
                    pos = end;
                    rest = tail;
                }
            }
            let zr = &self.zr[..need_z];
            let zi = &self.zi[..if is_fft { need_z } else { 0 }];
            execute(pool, parts, |_wi, (range, out_s, ws)| {
                let mut local = 0usize; // pixel offset into out_s
                let mut gr = range.start;
                while gr < range.end {
                    let (q, row0) = (gr / nh, gr % nh);
                    let rows = (nh - row0).min(range.end - gr);
                    let row1 = row0 + rows;
                    let (bi, ki) = (q / k, q % k);
                    let seg_px = ((row1 * m).min(grid.oh) - row0 * m) * grid.ow;
                    let seg = &mut out_s[local..local + seg_px];
                    let (ni_start, ni_end) = (row0 * grid.nw, row1 * grid.nw);
                    let mut done = ni_start;
                    while done < ni_end {
                        let cnt = NB.min(ni_end - done);
                        for pp in 0..p {
                            let off = (pp * k + ki) * bn + bi * n + done;
                            for (s, &v) in zr[off..off + cnt].iter().enumerate() {
                                ws.tre[s * p + pp] = v;
                            }
                            if is_fft {
                                for (s, &v) in zi[off..off + cnt].iter().enumerate() {
                                    ws.tim[s * p + pp] = v;
                                }
                            }
                        }
                        match &mut ws.codelets {
                            Codelets::Winograd { output, .. } => {
                                output.apply(&ws.tre[..cnt * p], cnt, &mut ws.ob[..cnt * m * m]);
                            }
                            Codelets::Fft(tf) => {
                                tf.inverse_valid(
                                    &ws.tre[..cnt * p],
                                    &ws.tim[..cnt * p],
                                    cnt,
                                    &mut ws.ob[..cnt * m * m],
                                );
                            }
                        }
                        for s in 0..cnt {
                            let ni = done + s;
                            let (ti, tj) = (ni / grid.nw, ni % grid.nw);
                            grid.scatter_rows(
                                &ws.ob[s * m * m..(s + 1) * m * m],
                                ti,
                                tj,
                                row0 * m,
                                seg,
                            );
                        }
                        done += cnt;
                    }
                    local += seg_px;
                    gr += rows;
                }
            });
        }
    }
}

/// Run one tiled convolution through a cached plan slot, rebuilding the
/// plan only when (algo, shape, weights) changed — the shared body of the
/// `WinogradLayer` / `FftConvLayer` wrappers.
pub fn run_cached(
    algo: ConvAlgorithm,
    x: &Tensor4,
    w: &Tensor4,
    cache: &mut Option<LayerPlan>,
    pool: Option<&ThreadPool>,
) -> Tensor4 {
    let fp = weights_fingerprint(w);
    let stale = match cache {
        Some(plan) => !plan.matches(algo, x, fp),
        None => true,
    };
    if stale {
        let nworkers = pool.map_or(1, |p| p.workers());
        *cache = Some(LayerPlan::new(algo, w, x.shape[2], x.shape[3], nworkers));
    }
    cache
        .as_mut()
        .expect("plan populated above")
        .run(x, pool)
}

/// Winograd kernel transform (no spatial flip — the Cook–Toom matrices
/// bake correlation in): V[P][K][C] from w (K, C, r, r).
fn wino_kernel_transform(w: &Tensor4, kernel_tf: &mut BatchSandwich, p: usize) -> Vec<f32> {
    let [k, c, r, _] = w.shape;
    let mut v = vec![0.0f32; p * k * c];
    let mut wb = vec![0.0f32; NB * r * r];
    let mut tb = vec![0.0f32; NB * p];
    for ki in 0..k {
        let mut ci0 = 0usize;
        let mut cnt = 0usize;
        for ci in 0..c {
            wb[cnt * r * r..(cnt + 1) * r * r].copy_from_slice(w.plane(ki, ci));
            cnt += 1;
            if cnt == NB || ci + 1 == c {
                kernel_tf.apply(&wb[..cnt * r * r], cnt, &mut tb[..cnt * p]);
                for s in 0..cnt {
                    for pp in 0..p {
                        v[(pp * k + ki) * c + ci0 + s] = tb[s * p + pp];
                    }
                }
                ci0 += cnt;
                cnt = 0;
            }
        }
    }
    v
}

/// FFT kernel transform (spatially flipped, implicit zero-pad):
/// V[P][K][C] re/im planes, plus the Gauss Vd/Vs precombinations.
fn fft_kernel_transform(
    w: &Tensor4,
    tf: &mut BatchDft,
    p: usize,
    gauss: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let [k, c, r, _] = w.shape;
    let mut vr = vec![0.0f32; p * k * c];
    let mut vi = vec![0.0f32; p * k * c];
    let (mut vd, mut vs) = if gauss {
        (vec![0.0f32; p * k * c], vec![0.0f32; p * k * c])
    } else {
        (Vec::new(), Vec::new())
    };
    let mut kb = vec![0.0f32; NB * r * r];
    let mut zre = vec![0.0f32; NB * p];
    let mut zim = vec![0.0f32; NB * p];
    for ki in 0..k {
        let mut ci0 = 0usize;
        let mut cnt = 0usize;
        for ci in 0..c {
            let wtile = w.plane(ki, ci);
            let dst = &mut kb[cnt * r * r..(cnt + 1) * r * r];
            for u in 0..r {
                for v in 0..r {
                    dst[u * r + v] = wtile[(r - 1 - u) * r + (r - 1 - v)];
                }
            }
            cnt += 1;
            if cnt == NB || ci + 1 == c {
                tf.forward(&kb[..cnt * r * r], cnt, r, &mut zre[..cnt * p], &mut zim[..cnt * p]);
                for pp in 0..p {
                    let off = (pp * k + ki) * c + ci0;
                    for s in 0..cnt {
                        let re = zre[s * p + pp];
                        let im = zim[s * p + pp];
                        vr[off + s] = re;
                        vi[off + s] = im;
                        if gauss {
                            vd[off + s] = im - re;
                            vs[off + s] = re + im;
                        }
                    }
                }
                ci0 += cnt;
                cnt = 0;
            }
        }
    }
    (vr, vi, vd, vs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct;

    fn tol(want: &Tensor4) -> f32 {
        2e-3 * want.max_abs().max(1.0)
    }

    #[test]
    fn plan_matches_direct_all_methods() {
        let x = Tensor4::random([2, 3, 13, 12], 810);
        let w = Tensor4::random([4, 3, 3, 3], 811);
        let want = direct::naive(&x, &w);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 4 },
            ConvAlgorithm::GaussFft { m: 4 },
        ] {
            let mut plan = LayerPlan::new(algo, &w, 13, 12, 1);
            let got = plan.run(&x, None);
            assert!(
                got.max_abs_diff(&want) < tol(&want),
                "{}: {}",
                algo.name(),
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        let x = Tensor4::random([3, 4, 17, 15], 820);
        let w = Tensor4::random([5, 4, 3, 3], 821);
        let pool = ThreadPool::new(4);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 6 },
            ConvAlgorithm::GaussFft { m: 6 },
        ] {
            let mut serial = LayerPlan::new(algo, &w, 17, 15, 1);
            let mut par = LayerPlan::new(algo, &w, 17, 15, 4);
            let a = serial.run(&x, None);
            let b = par.run(&x, Some(&pool));
            assert_eq!(a.shape, b.shape);
            // shard boundaries never change per-tile arithmetic
            assert!(a.max_abs_diff(&b) < 1e-6, "{}", algo.name());
        }
    }

    #[test]
    fn plan_reused_across_batch_sizes() {
        let w = Tensor4::random([2, 2, 3, 3], 830);
        let mut plan = LayerPlan::new(ConvAlgorithm::RegularFft { m: 4 }, &w, 10, 10, 1);
        for (b, seed) in [(1usize, 840u64), (4, 841), (2, 842)] {
            let x = Tensor4::random([b, 2, 10, 10], seed);
            let want = direct::naive(&x, &w);
            let got = plan.run(&x, None);
            assert!(got.max_abs_diff(&want) < tol(&want), "b={b}");
        }
    }

    #[test]
    fn hot_path_allocation_free_after_first_batch() {
        let w = Tensor4::random([3, 2, 3, 3], 850);
        let pool = ThreadPool::new(2);
        let mut plan = LayerPlan::new(ConvAlgorithm::GaussFft { m: 4 }, &w, 12, 12, 2);
        let x1 = Tensor4::random([2, 2, 12, 12], 851);
        let x2 = Tensor4::random([2, 2, 12, 12], 852);
        let o1 = plan.run(&x1, Some(&pool));
        let stamp = plan.arena_stamp();
        let o2 = plan.run(&x2, Some(&pool));
        assert_eq!(stamp, plan.arena_stamp(), "arenas reallocated on hot path");
        for (x, o) in [(&x1, &o1), (&x2, &o2)] {
            let want = direct::naive(x, &w);
            assert!(o.max_abs_diff(&want) < tol(&want));
        }
    }

    #[test]
    fn fingerprint_distinguishes_weights() {
        let a = Tensor4::random([2, 2, 3, 3], 860);
        let mut b = a.clone();
        b.data[7] += 1e-3;
        assert_ne!(weights_fingerprint(&a), weights_fingerprint(&b));
        assert_eq!(weights_fingerprint(&a), weights_fingerprint(&a.clone()));
    }

    #[test]
    fn run_cached_rebuilds_only_when_stale() {
        let x = Tensor4::random([1, 2, 9, 9], 870);
        let w1 = Tensor4::random([2, 2, 3, 3], 871);
        let w2 = Tensor4::random([2, 2, 3, 3], 872);
        let mut cache = None;
        let algo = ConvAlgorithm::Winograd { m: 3 };
        let got1 = run_cached(algo, &x, &w1, &mut cache, None);
        let fp1 = cache.as_ref().unwrap().weights_fp;
        let _ = run_cached(algo, &x, &w1, &mut cache, None);
        assert_eq!(fp1, cache.as_ref().unwrap().weights_fp, "no rebuild");
        let got2 = run_cached(algo, &x, &w2, &mut cache, None);
        assert_ne!(fp1, cache.as_ref().unwrap().weights_fp, "rebuilt");
        assert!(got1.max_abs_diff(&direct::naive(&x, &w1)) < 1e-3);
        assert!(got2.max_abs_diff(&direct::naive(&x, &w2)) < 1e-3);
    }
}
