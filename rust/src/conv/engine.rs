//! The stage-parallel convolution engine — one execution pipeline behind
//! all three transformed-convolution methods (Winograd, Regular-FFT,
//! Gauss-FFT).
//!
//! A [`LayerPlan`] is built **once** per (layer shape, algorithm): it
//! caches the transformed kernel tensor `V[P][K][C]` and owns grow-only
//! scratch arenas plus per-worker codelet state, so serving repeated
//! requests never re-transforms weights and never allocates on the hot
//! path (arena capacity is reached after the first batch).
//!
//! Each of the three stages is executed as one static fork-join over the
//! shared [`ThreadPool`] (paper §3, after Zlateski & Seung), with
//! equal-FLOP partitions:
//!
//! * **input transform** — sharded over the global tile index
//!   `(b, c, tile)`; every tile costs the same FLOPs, so `even_ranges`
//!   is the equal-FLOP split.  Tile granularity means batches smaller
//!   than the worker count still use every core (intra-image sharding).
//! * **element-wise stage** — sharded over the `P` transform elements;
//!   each element's `(K x C) @ (C x BN)` GEMM is independent, so shards
//!   write disjoint contiguous `&mut` panels of `Z` with no
//!   synchronization.
//! * **inverse transform** — sharded over global *tile rows*
//!   `(b, k, tile_row)`; a contiguous run of tile rows maps to a
//!   contiguous pixel range of the output tensor, so each worker gets a
//!   disjoint `&mut` output slice proven safe by the borrow checker.
//!
//! The input-transform stage writes `U[P][C][BN]` planes whose per-worker
//! regions are disjoint but *strided* (each worker owns a `(b, c)`-tile
//! run across all P planes), which no safe split can express — that one
//! stage writes through a [`SharedSlice`] whose disjointness argument is
//! documented at the call site.
//!
//! ## Fused execution mode (L3 fusion)
//!
//! The staged pipeline above is bandwidth-bound on modern CPUs precisely
//! because the full `U[P][C][BN]` / `Z[P][K][BN]` arenas spill out of
//! cache between the three fork-join barriers (the paper's roofline
//! analysis; L3 Fusion, Gelashvili/Shavit/Zlateski).  [`ExecMode::Fused`]
//! removes that traffic: **one** fork-join per batch in which each worker
//! carries a *panel* of `pb` tiles end-to-end — gather + input transform
//! into a worker-local `u[P][C][pb]`, all `P` element-wise GEMMs into a
//! worker-local `z[P][K][pb]`, inverse transform, scatter — with the
//! panel scratch sized (at plan build) to fit the per-worker cache
//! budget.  The transformed kernel `V[P][K][C]` is the only large operand
//! the fused loop streams; `U`/`Z` never exist at DRAM scale.
//!
//! Mode selection: [`PlanOptions::exec`] is `Auto` (fuse whenever a
//! useful panel fits the budget), or an explicit `Staged`/`Fused`
//! override; the scheduler resolves `Auto` through the roofline model's
//! fused-vs-staged DRAM-traffic estimate (`model::select::choose_exec`).
//!
//! ## Both variants, one plan
//!
//! A plan is *not* pinned to the mode it resolved at build time: the
//! staged arenas and the fused panels are independent pieces of scratch
//! hanging off the same cached kernel transform `V[P][K][C]`, so one
//! `LayerPlan` can serve **either** pipeline on any given batch via
//! [`LayerPlan::run_with_mode`] (the scheduler's per-batch tuning table
//! does exactly that).  [`PlanOptions::exec`] only sets the *default*
//! mode used by [`LayerPlan::run_into`]; fused capability is retained
//! whenever a panel fits the cache budget ([`LayerPlan::can_fuse`]).
//! Each variant's scratch grows on the first batch that uses it and can
//! be reclaimed independently ([`LayerPlan::trim_staged`] /
//! [`LayerPlan::trim_fused`]) without touching the kernel transform.

use super::batch_wino::BatchSandwich;
use super::fft_conv::FftVariant;
use super::gemm::{
    cgemm_acc_isa, cgemm_panel_acc_isa, gauss_gemm_acc_isa, gauss_panel_acc_isa, gemm_acc_isa,
    gemm_panel_isa, GaussScratch,
};
use super::tensor::Tensor4;
use super::tiles::TileGrid;
use super::ConvAlgorithm;
use crate::fft::batch_dft::BatchDft;
use crate::simd::transpose::{transpose, transpose_ld};
use crate::simd::Isa;
use crate::util::aligned::{stream_fence, stream_run, AlignedVec};
use crate::util::threadpool::{even_ranges, ThreadPool};
use crate::winograd::matrices::winograd_matrices_f32;
use std::marker::PhantomData;
use std::ops::Range;

/// Tiles transformed per batched-codelet invocation (amortizes the
/// transform-matrix panels across the register-blocked GEMM).
const NB: usize = 32;

/// Smallest fused panel worth running: below this the per-element GEMMs
/// degenerate to register-block edge cases and fusion stops paying.
/// Shared with the roofline model's fused feasibility cutoff
/// (`model::roofline::fused_layer_time`).
pub const MIN_PB: usize = 8;

/// Largest fused panel: beyond ~4 register blocks of tiles the panel
/// stops helping (V streaming amortization flattens) and only evicts
/// other working-set lines.  Shared with the roofline model.
pub const MAX_PB: usize = 64;

/// Default per-worker fused-scratch budget (bytes) when no machine model
/// is consulted: 1 MB, a typical modern-CPU L2 (and the model catalog's
/// most common core-exclusive cache size).
pub const DEFAULT_FUSED_BUDGET: usize = 1 << 20;

/// How a plan is allowed to execute (the configuration knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecPolicy {
    /// Fuse whenever a >= MIN_PB tile panel fits the cache budget
    /// (callers with a machine model make a roofline decision instead and
    /// pass `Staged`/`Fused` explicitly).
    #[default]
    Auto,
    /// Always run the three-stage arena pipeline.
    Staged,
    /// Always run the fused panel pipeline.
    Fused,
}

/// The execution mode a plan actually resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Staged,
    Fused,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Staged => "staged",
            ExecMode::Fused => "fused",
        }
    }
}

/// Plan-construction options.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    pub exec: ExecPolicy,
    /// per-worker cache budget (bytes) that sizes the fused tile panel
    pub fused_budget: usize,
    /// kernel set override — `None` resolves the process-wide default
    /// ([`Isa::resolved`]: runtime detection, or the `FFTCONV_FORCE_ISA`
    /// environment override).  Either way the value is clamped to what
    /// the host can execute and bound into the plan at construction.
    pub isa: Option<Isa>,
    /// the problem's symmetric zero-padding — the tiling grid gathers
    /// the halo as zeros, so the input tensor is never padded in memory
    pub pad: usize,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions {
            exec: ExecPolicy::Auto,
            fused_budget: DEFAULT_FUSED_BUDGET,
            isa: None,
            pad: 0,
        }
    }
}

/// Tiles per fused panel that keep one worker's fused scratch
/// (`u[P][C][pb]` + `z[P][K][pb]`, all planes) within `budget` bytes.
/// Returns 0 when even a single tile exceeds the budget — the fused
/// pipeline is then cache-infeasible for this layer (the big-channel
/// regime where the paper's blocked staged pipeline is the right shape).
pub fn fused_panel_tiles(
    p: usize,
    c: usize,
    k: usize,
    is_fft: bool,
    gauss: bool,
    budget: usize,
) -> usize {
    let u_planes = if gauss {
        3 // re, im, re+im
    } else if is_fft {
        2
    } else {
        1
    };
    let z_planes = if is_fft { 2 } else { 1 };
    let bytes_per_tile = 4 * p * (c * u_planes + k * z_planes);
    budget / bytes_per_tile.max(1)
}

/// FNV-1a over the weight tensor's bit pattern — the cheap identity check
/// plan caches use to decide whether a cached kernel transform is stale.
pub fn weights_fingerprint(w: &Tensor4) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &s in &w.shape {
        h ^= s as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for &v in &w.data {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Shared mutable view over an `f32` buffer for stage shards whose
/// disjoint write sets are strided (not expressible as sub-slices).
///
/// Safety contract: every index is written by at most one worker of the
/// fork-join, and the buffer is not read until the join.  Each `set` call
/// site documents why its index set is disjoint across workers.
struct SharedSlice<'a> {
    ptr: *mut f32,
    len: usize,
    _life: PhantomData<&'a mut [f32]>,
}

unsafe impl Send for SharedSlice<'_> {}
unsafe impl Sync for SharedSlice<'_> {}

impl<'a> SharedSlice<'a> {
    fn new(s: &'a mut [f32]) -> SharedSlice<'a> {
        SharedSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            _life: PhantomData,
        }
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other worker may read or write index `i` during this fork-join.
    #[inline]
    unsafe fn set(&self, i: usize, v: f32) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Write a contiguous run starting at index `i`.
    ///
    /// # Safety
    /// No other worker may read or write `i..i + src.len()` during this
    /// fork-join.
    #[inline]
    unsafe fn write_run(&self, i: usize, src: &[f32]) {
        debug_assert!(i + src.len() <= self.len);
        std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(i), src.len());
    }

    /// [`SharedSlice::write_run`] with non-temporal stores where the ISA
    /// allows (see [`crate::util::aligned::stream_run`]).  NT stores stay
    /// cache-coherent, so partial lines shared with a neighbouring
    /// worker's normal stores are safe; they are only weakly *ordered*,
    /// which the per-worker [`stream_fence`] before the join handles.
    ///
    /// # Safety
    /// Same contract as [`SharedSlice::write_run`].
    #[inline]
    unsafe fn stream(&self, i: usize, src: &[f32], isa: Isa) {
        debug_assert!(i + src.len() <= self.len);
        stream_run(self.ptr.add(i), src.as_ptr(), src.len(), isa);
    }
}

/// Run `f(i, part)` for every part — on the pool's static fork-join when a
/// pool is given, inline on the caller's thread otherwise (the serial path
/// used by the one-shot wrappers).
fn execute<T, F>(pool: Option<&ThreadPool>, parts: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Send + Sync,
{
    match pool {
        Some(p) => p.run_parts(parts, f),
        None => {
            for (i, part) in parts.into_iter().enumerate() {
                f(i, part);
            }
        }
    }
}

/// Split `buf` into per-range sub-slices of `unit` elements per item.
/// Ranges must be contiguous and tile `buf` exactly (as `even_ranges`
/// produces).  Shared with the scheduler's Direct/Im2col partitions.
pub(crate) fn split_units<'a>(
    buf: &'a mut [f32],
    ranges: &[Range<usize>],
    unit: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    for r in ranges {
        let take = (r.end - r.start) * unit;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        out.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty());
    out
}

/// The per-worker transform codelets (each worker owns its own scratch).
enum Codelets {
    Winograd {
        input: BatchSandwich,
        output: BatchSandwich,
    },
    Fft(BatchDft),
}

/// Per-worker state: codelets plus gather/transform/scatter buffers, all
/// allocated at plan build and reused across every batch.  The `f*`
/// vectors are the fused pipeline's cache-resident panel arenas
/// (`u[P][C][pb]` / `z[P][K][pb]`), grown on the first fused batch and
/// stable thereafter.
struct WorkerState {
    codelets: Codelets,
    /// gathered input tiles, cap x t x t (cap = max(NB, pb))
    xb: Vec<f32>,
    /// transform staging (re), cap x P — also the inverse-gather buffer
    tre: Vec<f32>,
    /// transform staging (im), cap x P (FFT only; empty for Winograd)
    tim: Vec<f32>,
    /// inverse output tiles, cap x m x m
    ob: Vec<f32>,
    gauss: GaussScratch,
    /// fused panel U planes: [P][C][pb] re / im / re+im — 64-byte-aligned,
    /// these are the SIMD panel GEMMs' streaming operands
    fur: AlignedVec,
    fui: AlignedVec,
    fus: AlignedVec,
    /// fused panel Z planes: [P][K][pb] re / im
    fzr: AlignedVec,
    fzi: AlignedVec,
    /// staged stage-1 staging: the (cnt, P) codelet output re-laid as
    /// (P, cnt) so every element row streams into `U` as one contiguous
    /// (non-temporal) run — grown on the first staged batch, freed by
    /// `trim_staged` (re / im / re+im)
    tpr: Vec<f32>,
    tpi: Vec<f32>,
    tps: Vec<f32>,
}

impl WorkerState {
    fn new(codelets: Codelets, t: usize, p: usize, m: usize, is_fft: bool, cap: usize) -> Self {
        WorkerState {
            codelets,
            xb: vec![0.0; cap * t * t],
            tre: vec![0.0; cap * p],
            tim: if is_fft { vec![0.0; cap * p] } else { Vec::new() },
            ob: vec![0.0; cap * m * m],
            gauss: GaussScratch::default(),
            fur: AlignedVec::new(),
            fui: AlignedVec::new(),
            fus: AlignedVec::new(),
            fzr: AlignedVec::new(),
            fzi: AlignedVec::new(),
            tpr: Vec::new(),
            tpi: Vec::new(),
            tps: Vec::new(),
        }
    }

    /// Grow the stage-1 element-major staging buffers (no-op after the
    /// first staged batch, or after a `trim_staged`-then-rerun).
    fn ensure_stage1(&mut self, need: usize, is_fft: bool, gauss: bool) {
        if self.tpr.len() < need {
            self.tpr.resize(need, 0.0);
        }
        if is_fft && self.tpi.len() < need {
            self.tpi.resize(need, 0.0);
        }
        if gauss && self.tps.len() < need {
            self.tps.resize(need, 0.0);
        }
    }

    /// Bytes of droppable staged-side staging scratch.
    fn staged_bytes(&self) -> usize {
        (self.tpr.len() + self.tpi.len() + self.tps.len()) * 4
    }

    /// Free the staged-side staging scratch (regrown on the next batch).
    fn trim_staged_scratch(&mut self) {
        self.tpr = Vec::new();
        self.tpi = Vec::new();
        self.tps = Vec::new();
    }

    /// Grow the fused panel arenas to the plan's fixed panel footprint
    /// (no-op after the first fused batch, or after a `trim`-then-rerun).
    fn ensure_fused(&mut self, need_u: usize, need_z: usize, is_fft: bool, gauss: bool) {
        if self.fur.len() < need_u {
            self.fur.resize(need_u);
        }
        if self.fzr.len() < need_z {
            self.fzr.resize(need_z);
        }
        if is_fft {
            if self.fui.len() < need_u {
                self.fui.resize(need_u);
            }
            if self.fzi.len() < need_z {
                self.fzi.resize(need_z);
            }
        }
        if gauss && self.fus.len() < need_u {
            self.fus.resize(need_u);
        }
        debug_assert!(
            self.fur.is_aligned() && self.fzr.is_aligned(),
            "fused panels must be 64-byte-aligned"
        );
    }

    /// Bytes of droppable fused-panel scratch (the shared Gauss buffers
    /// are accounted separately at the plan level).
    fn fused_bytes(&self) -> usize {
        let f32s = self.fur.len()
            + self.fui.len()
            + self.fus.len()
            + self.fzr.len()
            + self.fzi.len();
        f32s * 4
    }

    /// Free the droppable scratch (regrown on the next batch).
    fn trim(&mut self) {
        self.fur = AlignedVec::new();
        self.fui = AlignedVec::new();
        self.fus = AlignedVec::new();
        self.fzr = AlignedVec::new();
        self.fzi = AlignedVec::new();
        self.gauss.clear();
    }
}

/// A reusable, stage-parallel execution plan for one convolution layer.
pub struct LayerPlan {
    pub algo: ConvAlgorithm,
    /// input channels
    pub c: usize,
    /// output channels
    pub k: usize,
    /// input spatial size
    pub h: usize,
    pub w: usize,
    /// kernel size
    pub r: usize,
    /// output tile size
    pub m: usize,
    /// transform tile size t = m + r - 1
    pub t: usize,
    /// fingerprint of the weights the cached kernel transform belongs to
    pub weights_fp: u64,
    /// transform elements: t*t (Winograd) or th*t (FFT half spectrum)
    p: usize,
    variant: Option<FftVariant>,
    /// resolved execution mode (see [`PlanOptions::exec`])
    mode: ExecMode,
    /// resolved kernel set, bound at construction (clamped to the host) —
    /// every GEMM and codelet this plan runs uses exactly this ISA, so the
    /// per-batch hot path never re-detects or branches on features
    isa: Isa,
    /// tiles per fused panel (0 in staged mode)
    pb: usize,
    grid: TileGrid,
    // transformed kernel V[P][K][C], built once at plan construction
    vr: Vec<f32>,
    vi: Vec<f32>,
    vd: Vec<f32>,
    vs: Vec<f32>,
    // grow-only hot-path arenas (U[P][C][BN], Z[P][K][BN] planes),
    // 64-byte-aligned for the SIMD kernels
    ur: AlignedVec,
    ui: AlignedVec,
    us: AlignedVec,
    zr: AlignedVec,
    zi: AlignedVec,
    workers: Vec<WorkerState>,
}

impl LayerPlan {
    /// Build a plan: constructs per-worker codelets and transforms the
    /// kernel once.  `h`/`w` are the input spatial dims the plan serves
    /// (the batch size may vary call to call).
    pub fn new(
        algo: ConvAlgorithm,
        weights: &Tensor4,
        h: usize,
        w: usize,
        nworkers: usize,
    ) -> LayerPlan {
        Self::with_options(algo, weights, h, w, nworkers, PlanOptions::default())
    }

    /// [`LayerPlan::new`] with explicit execution options (mode override
    /// and fused cache budget).
    pub fn with_options(
        algo: ConvAlgorithm,
        weights: &Tensor4,
        h: usize,
        w: usize,
        nworkers: usize,
        opts: PlanOptions,
    ) -> LayerPlan {
        let m = algo.tile_m().expect("LayerPlan requires a tiled algorithm");
        let [k, c, r, r2] = weights.shape;
        assert_eq!(r, r2, "non-square kernel");
        let variant = match algo {
            ConvAlgorithm::Winograd { .. } => None,
            ConvAlgorithm::RegularFft { .. } => Some(FftVariant::Regular),
            ConvAlgorithm::GaussFft { .. } => Some(FftVariant::Gauss),
            _ => unreachable!("tile_m() returned Some for a non-tiled algorithm"),
        };
        let grid = TileGrid::with_pad(h, w, m, r, opts.pad);
        let t = m + r - 1;
        let nworkers = nworkers.max(1);
        let gauss = variant == Some(FftVariant::Gauss);
        let is_fft = variant.is_some();

        let p = match variant {
            None => t * t,
            Some(_) => (t / 2 + 1) * t,
        };
        let isa = opts.isa.unwrap_or_else(Isa::resolved).clamp_to_host();
        let fit = fused_panel_tiles(p, c, k, is_fft, gauss, opts.fused_budget);
        // fused *capability* (pb > 0) is kept whenever a useful panel fits
        // the budget, regardless of the default mode below — the per-batch
        // tuning table may run the non-default variant on the same plan.
        // An explicit `Fused` pin forces at least MIN_PB tiles even when
        // the budget says otherwise (the caller asked for it).
        let pb = if fit >= MIN_PB {
            fit.min(MAX_PB)
        } else if opts.exec == ExecPolicy::Fused {
            fit.clamp(MIN_PB, MAX_PB)
        } else {
            0
        };
        let mode = match opts.exec {
            ExecPolicy::Staged => ExecMode::Staged,
            ExecPolicy::Fused => ExecMode::Fused,
            ExecPolicy::Auto => {
                if fit >= MIN_PB {
                    ExecMode::Fused
                } else {
                    ExecMode::Staged
                }
            }
        };
        let cap = NB.max(pb);

        let (workers, vr, vi, vd, vs) = match variant {
            None => {
                let (at, g, bt) = winograd_matrices_f32(m, r);
                let mut workers = Vec::with_capacity(nworkers);
                for _ in 0..nworkers {
                    workers.push(WorkerState::new(
                        Codelets::Winograd {
                            input: BatchSandwich::with_isa(&bt, t, t, isa),
                            output: BatchSandwich::with_isa(&at, m, t, isa),
                        },
                        t,
                        p,
                        m,
                        false,
                        cap,
                    ));
                }
                let mut kernel_tf = BatchSandwich::with_isa(&g, t, r, isa);
                let vr = wino_kernel_transform(weights, &mut kernel_tf, p);
                (workers, vr, Vec::new(), Vec::new(), Vec::new())
            }
            Some(_) => {
                let tf = BatchDft::with_isa(m, r, isa);
                debug_assert_eq!(p, tf.th * tf.t);
                let mut workers = Vec::with_capacity(nworkers);
                for _ in 0..nworkers {
                    workers.push(WorkerState::new(Codelets::Fft(tf.clone()), t, p, m, true, cap));
                }
                let mut kernel_tf = tf;
                let (vr, vi, vd, vs) = fft_kernel_transform(weights, &mut kernel_tf, p, gauss);
                (workers, vr, vi, vd, vs)
            }
        };

        LayerPlan {
            algo,
            c,
            k,
            h,
            w,
            r,
            m,
            t,
            weights_fp: weights_fingerprint(weights),
            p,
            variant,
            mode,
            isa,
            pb,
            grid,
            vr,
            vi,
            vd,
            vs,
            ur: AlignedVec::new(),
            ui: AlignedVec::new(),
            us: AlignedVec::new(),
            zr: AlignedVec::new(),
            zi: AlignedVec::new(),
            workers,
        }
    }

    /// The kernel set this plan bound at construction (after clamping the
    /// requested/resolved ISA to the host's capability).
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Shape of the output for a batch of `b` images.
    pub fn output_shape(&self, b: usize) -> [usize; 4] {
        [b, self.k, self.grid.oh, self.grid.ow]
    }

    /// The symmetric zero-padding this plan's grid gathers.
    pub fn pad(&self) -> usize {
        self.grid.pad
    }

    /// Does this plan serve (algo, input shape, padding, these weights)?
    pub fn matches(&self, algo: ConvAlgorithm, x: &Tensor4, pad: usize, weights_fp: u64) -> bool {
        self.algo == algo
            && x.shape[1] == self.c
            && x.shape[2] == self.h
            && x.shape[3] == self.w
            && self.grid.pad == pad
            && self.weights_fp == weights_fp
    }

    /// Arena identity stamp (pointers + lengths of every hot-path arena,
    /// including each worker's fused panels): unchanged across two
    /// same-shape runs ⇔ the hot path did not allocate.
    pub fn arena_stamp(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for buf in [&self.ur, &self.ui, &self.us, &self.zr, &self.zi] {
            v.push((buf.as_ptr() as usize, buf.len()));
        }
        for ws in &self.workers {
            for buf in [&ws.fur, &ws.fui, &ws.fus, &ws.fzr, &ws.fzi] {
                v.push((buf.as_ptr() as usize, buf.len()));
            }
            for buf in [&ws.tpr, &ws.tpi, &ws.tps] {
                v.push((buf.as_ptr() as usize, buf.len()));
            }
        }
        v
    }

    /// The *default* execution mode — what a plain [`LayerPlan::run_into`]
    /// runs.  Resolved from [`PlanOptions::exec`] at build time; callers
    /// holding fresher information (the scheduler's tuning table) override
    /// it per batch with [`LayerPlan::run_with_mode`] or durably with
    /// [`LayerPlan::set_exec_mode`].
    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Re-pin the default execution mode.  Panics if `Fused` is requested
    /// on a plan whose panel never fit the cache budget (`can_fuse()` is
    /// false).
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        assert!(
            mode != ExecMode::Fused || self.can_fuse(),
            "fused exec requested but no panel fits the cache budget"
        );
        self.mode = mode;
    }

    /// Whether the fused panel pipeline is available on this plan (a
    /// `>= 1` tile panel fit the cache budget at build time).
    pub fn can_fuse(&self) -> bool {
        self.pb > 0
    }

    /// Tiles per fused panel (0 when fusion is unavailable).
    pub fn panel_tiles(&self) -> usize {
        self.pb
    }

    /// Bytes held by the staged variant's droppable scratch (the global
    /// `U`/`Z` arenas plus the per-worker stage-1 staging) — what
    /// [`LayerPlan::trim_staged`] frees, minus the shared Gauss buffers.
    pub fn staged_arena_bytes(&self) -> usize {
        let f32s =
            self.ur.len() + self.ui.len() + self.us.len() + self.zr.len() + self.zi.len();
        f32s * 4 + self.workers.iter().map(|w| w.staged_bytes()).sum::<usize>()
    }

    /// Bytes held by the fused variant's droppable scratch (every worker's
    /// cache-resident panels) — what [`LayerPlan::trim_fused`] frees,
    /// minus the shared Gauss buffers.
    pub fn fused_arena_bytes(&self) -> usize {
        self.workers.iter().map(|w| w.fused_bytes()).sum::<usize>()
    }

    /// Bytes of per-worker Gauss recombination scratch — grown by either
    /// pipeline of a Gauss-FFT plan, freed by either trim (it regrows
    /// transparently, like all droppable scratch).
    fn gauss_bytes(&self) -> usize {
        self.workers.iter().map(|w| w.gauss.bytes()).sum::<usize>()
    }

    /// Bytes held by droppable scratch across both exec variants — exactly
    /// what [`LayerPlan::trim`] frees.
    pub fn arena_bytes(&self) -> usize {
        self.staged_arena_bytes() + self.fused_arena_bytes() + self.gauss_bytes()
    }

    /// Total resident bytes: droppable arenas plus the kernel transform
    /// and the fixed per-worker codelet buffers (what a byte-aware plan
    /// cache charges this plan for).
    pub fn resident_bytes(&self) -> usize {
        let kernel =
            (self.vr.len() + self.vi.len() + self.vd.len() + self.vs.len()) * 4;
        let fixed: usize = self
            .workers
            .iter()
            .map(|w| (w.xb.len() + w.tre.len() + w.tim.len() + w.ob.len()) * 4)
            .sum();
        kernel + fixed + self.arena_bytes()
    }

    /// Free only the staged variant's scratch (the global `U`/`Z` arenas,
    /// plus the shared Gauss buffers).  The fused panels — and, always,
    /// the kernel transform — survive, so a plan serving mostly-fused
    /// traffic can shed its staged high-water mark without a fused warm-up
    /// on the next batch.
    pub fn trim_staged(&mut self) {
        self.ur = AlignedVec::new();
        self.ui = AlignedVec::new();
        self.us = AlignedVec::new();
        self.zr = AlignedVec::new();
        self.zi = AlignedVec::new();
        for ws in &mut self.workers {
            ws.gauss.clear();
            ws.trim_staged_scratch();
        }
    }

    /// Free only the fused variant's scratch (every worker's panels, plus
    /// the shared Gauss buffers), keeping the staged arenas and the kernel
    /// transform.
    pub fn trim_fused(&mut self) {
        for ws in &mut self.workers {
            ws.trim();
        }
    }

    /// Free the batch-scale scratch of *both* variants (staged `U`/`Z`
    /// arenas, fused panels, Gauss recombination buffers) while keeping
    /// the kernel transform and codelets — an idle plan shrinks to its
    /// `V[P][K][C]` planes and regrows scratch transparently on its next
    /// batch.
    pub fn trim(&mut self) {
        self.trim_staged();
        self.trim_fused();
    }

    /// Convenience wrapper over [`LayerPlan::run_into`].
    pub fn run(&mut self, x: &Tensor4, pool: Option<&ThreadPool>) -> Tensor4 {
        let mut out = Tensor4::zeros(self.output_shape(x.shape[0]));
        self.run_into(x, &mut out, pool);
        out
    }

    /// Execute the plan over `x`, writing into `out` — either the
    /// three-stage arena pipeline or the fused panel pipeline, per the
    /// plan's *default* mode (see [`LayerPlan::run_with_mode`] for a
    /// per-batch override).
    ///
    /// With `Some(pool)`, work forks across the pool's workers with
    /// statically precomputed equal-FLOP shards; with `None` it runs
    /// serially on the caller's thread (identical numerics either way —
    /// shard and panel boundaries never change any per-tile or per-GEMM
    /// arithmetic).
    pub fn run_into(&mut self, x: &Tensor4, out: &mut Tensor4, pool: Option<&ThreadPool>) {
        self.run_with_mode(x, out, pool, self.mode);
    }

    /// Execute the plan with an explicit execution mode for *this batch
    /// only* — the entry point of the scheduler's per-batch staged/fused
    /// re-resolution.  Both variants share the cached kernel transform;
    /// each grows (and keeps) its own scratch on the first batch that
    /// uses it.  Panics if `Fused` is requested but no panel fits
    /// ([`LayerPlan::can_fuse`] is false).
    pub fn run_with_mode(
        &mut self,
        x: &Tensor4,
        out: &mut Tensor4,
        pool: Option<&ThreadPool>,
        mode: ExecMode,
    ) {
        let [b, c, h, w] = x.shape;
        assert_eq!(c, self.c, "channel mismatch");
        assert_eq!((h, w), (self.h, self.w), "input spatial shape mismatch");
        assert_eq!(out.shape, self.output_shape(b), "output shape mismatch");
        match mode {
            ExecMode::Staged => self.run_staged(x, out, pool),
            ExecMode::Fused => {
                assert!(
                    self.can_fuse(),
                    "fused exec requested but no panel fits the cache budget"
                );
                self.run_fused(x, out, pool);
            }
        }
    }

    /// The staged pipeline: three fork-join stages over the global
    /// `U[P][C][BN]` / `Z[P][K][BN]` arenas.
    fn run_staged(&mut self, x: &Tensor4, out: &mut Tensor4, pool: Option<&ThreadPool>) {
        let [b, c, _, _] = x.shape;
        let grid = self.grid;
        let (k, m, t, p) = (self.k, self.m, self.t, self.p);
        let n = grid.tiles();
        let bn = b * n;
        let is_fft = self.variant.is_some();
        let gauss = self.variant == Some(FftVariant::Gauss);
        let nw = self.workers.len();

        // grow-only arenas: no allocation once the high-water batch is seen
        let need_u = p * c * bn;
        let need_z = p * k * bn;
        if self.ur.len() < need_u {
            self.ur.resize(need_u);
        }
        if self.zr.len() < need_z {
            self.zr.resize(need_z);
        }
        if is_fft {
            if self.ui.len() < need_u {
                self.ui.resize(need_u);
            }
            if self.zi.len() < need_z {
                self.zi.resize(need_z);
            }
        }
        if gauss && self.us.len() < need_u {
            self.us.resize(need_u);
        }
        debug_assert!(
            self.ur.is_aligned() && self.zr.is_aligned(),
            "staged arenas must be 64-byte-aligned"
        );

        // ---- stage 1: input transform, sharded over (b, c, tile) ----
        {
            let shards = even_ranges(b * c * n, nw);
            let u_re = SharedSlice::new(&mut self.ur[..need_u]);
            let u_im = if is_fft {
                Some(SharedSlice::new(&mut self.ui[..need_u]))
            } else {
                None
            };
            let u_s = if gauss {
                Some(SharedSlice::new(&mut self.us[..need_u]))
            } else {
                None
            };
            let isa = self.isa;
            let parts: Vec<(Range<usize>, &mut WorkerState)> =
                shards.into_iter().zip(self.workers.iter_mut()).collect();
            execute(pool, parts, |_wi, (range, ws)| {
                ws.ensure_stage1(NB * p, is_fft, gauss);
                let mut g = range.start;
                while g < range.end {
                    let bc = g / n;
                    let ni0 = g % n;
                    let (bi, ci) = (bc / c, bc % c);
                    let cnt = NB.min(n - ni0).min(range.end - g);
                    let plane = x.plane(bi, ci);
                    for s in 0..cnt {
                        let ni = ni0 + s;
                        let (ti, tj) = (ni / grid.nw, ni % grid.nw);
                        grid.gather(plane, ti, tj, &mut ws.xb[s * t * t..(s + 1) * t * t]);
                    }
                    match &mut ws.codelets {
                        Codelets::Winograd { input, .. } => {
                            input.apply(&ws.xb[..cnt * t * t], cnt, &mut ws.tre[..cnt * p]);
                        }
                        Codelets::Fft(tf) => {
                            tf.forward(
                                &ws.xb[..cnt * t * t],
                                cnt,
                                t,
                                &mut ws.tre[..cnt * p],
                                &mut ws.tim[..cnt * p],
                            );
                        }
                    }
                    // Re-lay the (cnt, P) codelet output as (P, cnt) so
                    // each element row lands in U as ONE contiguous run —
                    // streamed non-temporally, since U is only read a full
                    // stage later (write-allocate traffic saved).
                    // Disjointness: workers own disjoint (bi, ci, ni)
                    // ranges, and U index (pp*c + ci)*bn + bi*n + ni is
                    // injective in (ci, bi, ni) for every pp.
                    let base = bi * n + ni0;
                    transpose(&mut ws.tpr[..p * cnt], &ws.tre[..cnt * p], cnt, p, isa);
                    if is_fft {
                        transpose(&mut ws.tpi[..p * cnt], &ws.tim[..cnt * p], cnt, p, isa);
                    }
                    if gauss {
                        for i in 0..p * cnt {
                            ws.tps[i] = ws.tpr[i] + ws.tpi[i];
                        }
                    }
                    for pp in 0..p {
                        let off = (pp * c + ci) * bn + base;
                        unsafe { u_re.stream(off, &ws.tpr[pp * cnt..(pp + 1) * cnt], isa) };
                        if let Some(u_im) = &u_im {
                            unsafe { u_im.stream(off, &ws.tpi[pp * cnt..(pp + 1) * cnt], isa) };
                            if let Some(u_s) = &u_s {
                                unsafe { u_s.stream(off, &ws.tps[pp * cnt..(pp + 1) * cnt], isa) };
                            }
                        }
                    }
                    g += cnt;
                }
                // NT stores are weakly ordered: publish them before this
                // worker reaches the stage's join barrier.
                stream_fence();
            });
        }

        // ---- stage 2: element-wise GEMMs, sharded over the P elements ----
        {
            let shards = even_ranges(p, nw);
            let zr_parts = split_units(&mut self.zr[..need_z], &shards, k * bn);
            let zi_parts: Vec<&mut [f32]> = if is_fft {
                split_units(&mut self.zi[..need_z], &shards, k * bn)
            } else {
                // Winograd has no imaginary plane: hand out empty slices
                (0..nw).map(|_| Default::default()).collect()
            };
            let ur = &self.ur[..need_u];
            let ui = &self.ui[..if is_fft { need_u } else { 0 }];
            let us = &self.us[..if gauss { need_u } else { 0 }];
            let (vr, vi, vd, vs) = (&self.vr, &self.vi, &self.vd, &self.vs);
            let isa = self.isa;
            let mut parts = Vec::with_capacity(nw);
            for (((range, zr_s), zi_s), ws) in shards
                .iter()
                .cloned()
                .zip(zr_parts)
                .zip(zi_parts)
                .zip(self.workers.iter_mut())
            {
                parts.push((range, zr_s, zi_s, ws));
            }
            execute(pool, parts, |_wi, (range, zr_s, zi_s, ws)| {
                for (idx, pp) in range.enumerate() {
                    let z0 = idx * k * bn;
                    let zr_p = &mut zr_s[z0..z0 + k * bn];
                    zr_p.fill(0.0);
                    let ur_p = &ur[pp * c * bn..(pp + 1) * c * bn];
                    let vr_p = &vr[pp * k * c..(pp + 1) * k * c];
                    if !is_fft {
                        // Z_p (K x BN) = V_p (K x C) @ U_p (C x BN)
                        gemm_acc_isa(zr_p, vr_p, ur_p, k, c, bn, isa);
                        continue;
                    }
                    let zi_p = &mut zi_s[z0..z0 + k * bn];
                    zi_p.fill(0.0);
                    let ui_p = &ui[pp * c * bn..(pp + 1) * c * bn];
                    let vi_p = &vi[pp * k * c..(pp + 1) * k * c];
                    if gauss {
                        // transposed Gauss: t1 = Vr@Us, t2 = Vd@Ur, t3 = Vs@Ui
                        // (gauss_gemm_acc computes t1 = arg_us@arg_vr etc., so
                        // the kernel-side planes go in the "u" slots and vice
                        // versa — identical to the pre-engine layer code)
                        gauss_gemm_acc_isa(
                            zr_p,
                            zi_p,
                            &vd[pp * k * c..(pp + 1) * k * c], // arg ur -> t2 lhs
                            &vs[pp * k * c..(pp + 1) * k * c], // arg ui -> t3 lhs
                            vr_p,                              // arg us -> t1 lhs
                            &us[pp * c * bn..(pp + 1) * c * bn], // arg vr -> t1 rhs
                            ur_p,                              // arg vd -> t2 rhs
                            ui_p,                              // arg vs -> t3 rhs
                            k,
                            c,
                            bn,
                            &mut ws.gauss,
                            isa,
                        );
                    } else {
                        cgemm_acc_isa(zr_p, zi_p, vr_p, vi_p, ur_p, ui_p, k, c, bn, isa);
                    }
                }
            });
        }

        // ---- stage 3: pruned inverse + scatter, sharded over (b, k, tile row) ----
        {
            let nh = grid.nh;
            let plane_len = grid.oh * grid.ow;
            let shards = even_ranges(b * k * nh, nw);
            // a contiguous run of global tile rows is a contiguous pixel
            // range of out.data, so the split below is a safe partition
            let addr = |gr: usize| -> usize {
                let (q, row) = (gr / nh, gr % nh);
                q * plane_len + (row * m).min(grid.oh) * grid.ow
            };
            let mut parts = Vec::with_capacity(nw);
            {
                let mut rest: &mut [f32] = &mut out.data[..];
                let mut pos = 0usize;
                for (range, ws) in shards.iter().cloned().zip(self.workers.iter_mut()) {
                    let end = addr(range.end);
                    let (head, tail) = std::mem::take(&mut rest).split_at_mut(end - pos);
                    parts.push((range, head, ws));
                    pos = end;
                    rest = tail;
                }
            }
            let zr = &self.zr[..need_z];
            let zi = &self.zi[..if is_fft { need_z } else { 0 }];
            let isa = self.isa;
            execute(pool, parts, |_wi, (range, out_s, ws)| {
                let mut local = 0usize; // pixel offset into out_s
                let mut gr = range.start;
                while gr < range.end {
                    let (q, row0) = (gr / nh, gr % nh);
                    let rows = (nh - row0).min(range.end - gr);
                    let row1 = row0 + rows;
                    let (bi, ki) = (q / k, q % k);
                    let seg_px = ((row1 * m).min(grid.oh) - row0 * m) * grid.ow;
                    let seg = &mut out_s[local..local + seg_px];
                    let (ni_start, ni_end) = (row0 * grid.nw, row1 * grid.nw);
                    let mut done = ni_start;
                    while done < ni_end {
                        let cnt = NB.min(ni_end - done);
                        // gather the (P, cnt) arena stripe (rows k*bn
                        // apart) back into tile-major (cnt, P) staging:
                        // one strided transpose per plane
                        let zb = ki * bn + bi * n + done;
                        transpose_ld(&mut ws.tre[..cnt * p], &zr[zb..], p, cnt, k * bn, p, isa);
                        if is_fft {
                            transpose_ld(&mut ws.tim[..cnt * p], &zi[zb..], p, cnt, k * bn, p, isa);
                        }
                        match &mut ws.codelets {
                            Codelets::Winograd { output, .. } => {
                                output.apply(&ws.tre[..cnt * p], cnt, &mut ws.ob[..cnt * m * m]);
                            }
                            Codelets::Fft(tf) => {
                                tf.inverse_valid(
                                    &ws.tre[..cnt * p],
                                    &ws.tim[..cnt * p],
                                    cnt,
                                    &mut ws.ob[..cnt * m * m],
                                );
                            }
                        }
                        for s in 0..cnt {
                            let ni = done + s;
                            let (ti, tj) = (ni / grid.nw, ni % grid.nw);
                            grid.scatter_rows(
                                &ws.ob[s * m * m..(s + 1) * m * m],
                                ti,
                                tj,
                                row0 * m,
                                seg,
                            );
                        }
                        done += cnt;
                    }
                    local += seg_px;
                    gr += rows;
                }
            });
        }
    }

    /// The fused panel pipeline: **one** fork-join per batch, sharded over
    /// the global `(image, tile)` index.  Each worker walks its tile range
    /// in panels of `pb` tiles and carries every panel end-to-end — gather
    /// + input transform into its local `u[P][C][pb]`, all `P` per-element
    /// GEMMs into its local `z[P][K][pb]`, inverse transform + scatter —
    /// so the transform intermediates never leave its cache budget.  Only
    /// the input image, the transformed kernel `V`, and the output cross
    /// DRAM.
    fn run_fused(&mut self, x: &Tensor4, out: &mut Tensor4, pool: Option<&ThreadPool>) {
        let [b, c, _, _] = x.shape;
        let grid = self.grid;
        let (k, m, t, p, pb) = (self.k, self.m, self.t, self.p, self.pb);
        let n = grid.tiles();
        let is_fft = self.variant.is_some();
        let gauss = self.variant == Some(FftVariant::Gauss);
        let nw = self.workers.len();
        let plane_len = grid.oh * grid.ow;

        let shards = even_ranges(b * n, nw);
        // Disjointness: output tiles partition each (image, k) plane, and
        // every global (image, tile) index belongs to exactly one worker's
        // range, so no output element is written by two workers.  The
        // write set per tile is strided across all K planes, which no safe
        // split can express — same argument as the staged U writes.
        let out_sh = SharedSlice::new(&mut out.data[..]);
        let (vr, vi, vd, vs) = (&self.vr, &self.vi, &self.vd, &self.vs);
        let isa = self.isa;
        let parts: Vec<(Range<usize>, &mut WorkerState)> =
            shards.into_iter().zip(self.workers.iter_mut()).collect();
        execute(pool, parts, |_wi, (range, ws)| {
            ws.ensure_fused(p * c * pb, p * k * pb, is_fft, gauss);
            let mut g = range.start;
            while g < range.end {
                let bi = g / n;
                let ni0 = g % n;
                // panels never straddle an image boundary (the gather
                // source plane is per-image)
                let cnt = pb.min(n - ni0).min(range.end - g);

                // -- fused stage A: gather + input transform into u --
                for ci in 0..c {
                    let plane = x.plane(bi, ci);
                    for s in 0..cnt {
                        let ni = ni0 + s;
                        grid.gather(
                            plane,
                            ni / grid.nw,
                            ni % grid.nw,
                            &mut ws.xb[s * t * t..(s + 1) * t * t],
                        );
                    }
                    match &mut ws.codelets {
                        Codelets::Winograd { input, .. } => {
                            input.apply_panel(
                                &ws.xb[..cnt * t * t],
                                cnt,
                                &mut ws.fur,
                                ci * cnt,
                                c * cnt,
                            );
                        }
                        Codelets::Fft(tf) => {
                            tf.forward_panel(
                                &ws.xb[..cnt * t * t],
                                cnt,
                                t,
                                &mut ws.fur,
                                &mut ws.fui,
                                ci * cnt,
                                c * cnt,
                            );
                        }
                    }
                }
                if gauss {
                    for i in 0..p * c * cnt {
                        ws.fus[i] = ws.fur[i] + ws.fui[i];
                    }
                }

                // -- fused stage B: all P element-wise GEMMs on the panel --
                for pp in 0..p {
                    let u0 = pp * c * cnt;
                    let z0 = pp * k * cnt;
                    let zr_p = &mut ws.fzr[z0..z0 + k * cnt];
                    zr_p.fill(0.0);
                    let ur_p = &ws.fur[u0..u0 + c * cnt];
                    let vr_p = &vr[pp * k * c..(pp + 1) * k * c];
                    if !is_fft {
                        // Z_p (K x cnt) = V_p (K x C) @ U_p (C x cnt)
                        gemm_panel_isa(zr_p, vr_p, ur_p, k, c, cnt, 1.0, isa);
                        continue;
                    }
                    let zi_p = &mut ws.fzi[z0..z0 + k * cnt];
                    zi_p.fill(0.0);
                    let ui_p = &ws.fui[u0..u0 + c * cnt];
                    let vi_p = &vi[pp * k * c..(pp + 1) * k * c];
                    if gauss {
                        gauss_panel_acc_isa(
                            zr_p,
                            zi_p,
                            vr_p,
                            &vd[pp * k * c..(pp + 1) * k * c],
                            &vs[pp * k * c..(pp + 1) * k * c],
                            ur_p,
                            ui_p,
                            &ws.fus[u0..u0 + c * cnt],
                            k,
                            c,
                            cnt,
                            &mut ws.gauss,
                            isa,
                        );
                    } else {
                        cgemm_panel_acc_isa(zr_p, zi_p, vr_p, vi_p, ur_p, ui_p, k, c, cnt, isa);
                    }
                }

                // -- fused stage C: inverse transform + scatter --
                for ki in 0..k {
                    // panel rows sit k*cnt apart: one strided transpose
                    // gathers the (P, cnt) plane into tile-major staging
                    let zb = ki * cnt;
                    transpose_ld(&mut ws.tre[..cnt * p], &ws.fzr[zb..], p, cnt, k * cnt, p, isa);
                    if is_fft {
                        let zi = &ws.fzi[zb..];
                        transpose_ld(&mut ws.tim[..cnt * p], zi, p, cnt, k * cnt, p, isa);
                    }
                    match &mut ws.codelets {
                        Codelets::Winograd { output, .. } => {
                            output.apply(&ws.tre[..cnt * p], cnt, &mut ws.ob[..cnt * m * m]);
                        }
                        Codelets::Fft(tf) => {
                            tf.inverse_valid(
                                &ws.tre[..cnt * p],
                                &ws.tim[..cnt * p],
                                cnt,
                                &mut ws.ob[..cnt * m * m],
                            );
                        }
                    }
                    let plane0 = (bi * k + ki) * plane_len;
                    for s in 0..cnt {
                        let ni = ni0 + s;
                        let tile = &ws.ob[s * m * m..(s + 1) * m * m];
                        grid.scatter_spans(ni / grid.nw, ni % grid.nw, |dst, src, len| {
                            // SAFETY: see the disjointness note above
                            unsafe { out_sh.write_run(plane0 + dst, &tile[src..src + len]) };
                        });
                    }
                }

                g += cnt;
            }
        });
    }
}

/// Run one tiled convolution through a cached plan slot, rebuilding the
/// plan only when (algo, shape, weights) changed — the shared body of the
/// `WinogradLayer` / `FftConvLayer` wrappers.
pub fn run_cached(
    algo: ConvAlgorithm,
    x: &Tensor4,
    w: &Tensor4,
    cache: &mut Option<LayerPlan>,
    pool: Option<&ThreadPool>,
) -> Tensor4 {
    let fp = weights_fingerprint(w);
    let stale = match cache {
        Some(plan) => !plan.matches(algo, x, 0, fp),
        None => true,
    };
    if stale {
        let nworkers = pool.map_or(1, |p| p.workers());
        *cache = Some(LayerPlan::new(algo, w, x.shape[2], x.shape[3], nworkers));
    }
    cache
        .as_mut()
        .expect("plan populated above")
        .run(x, pool)
}

/// Winograd kernel transform (no spatial flip — the Cook–Toom matrices
/// bake correlation in): V[P][K][C] from w (K, C, r, r).
fn wino_kernel_transform(w: &Tensor4, kernel_tf: &mut BatchSandwich, p: usize) -> Vec<f32> {
    let [k, c, r, _] = w.shape;
    let mut v = vec![0.0f32; p * k * c];
    let mut wb = vec![0.0f32; NB * r * r];
    let mut tb = vec![0.0f32; NB * p];
    for ki in 0..k {
        let mut ci0 = 0usize;
        let mut cnt = 0usize;
        for ci in 0..c {
            wb[cnt * r * r..(cnt + 1) * r * r].copy_from_slice(w.plane(ki, ci));
            cnt += 1;
            if cnt == NB || ci + 1 == c {
                kernel_tf.apply(&wb[..cnt * r * r], cnt, &mut tb[..cnt * p]);
                for s in 0..cnt {
                    for pp in 0..p {
                        v[(pp * k + ki) * c + ci0 + s] = tb[s * p + pp];
                    }
                }
                ci0 += cnt;
                cnt = 0;
            }
        }
    }
    v
}

/// FFT kernel transform (spatially flipped, implicit zero-pad):
/// V[P][K][C] re/im planes, plus the Gauss Vd/Vs precombinations.
fn fft_kernel_transform(
    w: &Tensor4,
    tf: &mut BatchDft,
    p: usize,
    gauss: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let [k, c, r, _] = w.shape;
    let mut vr = vec![0.0f32; p * k * c];
    let mut vi = vec![0.0f32; p * k * c];
    let (mut vd, mut vs) = if gauss {
        (vec![0.0f32; p * k * c], vec![0.0f32; p * k * c])
    } else {
        (Vec::new(), Vec::new())
    };
    let mut kb = vec![0.0f32; NB * r * r];
    let mut zre = vec![0.0f32; NB * p];
    let mut zim = vec![0.0f32; NB * p];
    for ki in 0..k {
        let mut ci0 = 0usize;
        let mut cnt = 0usize;
        for ci in 0..c {
            let wtile = w.plane(ki, ci);
            let dst = &mut kb[cnt * r * r..(cnt + 1) * r * r];
            for u in 0..r {
                for v in 0..r {
                    dst[u * r + v] = wtile[(r - 1 - u) * r + (r - 1 - v)];
                }
            }
            cnt += 1;
            if cnt == NB || ci + 1 == c {
                tf.forward(&kb[..cnt * r * r], cnt, r, &mut zre[..cnt * p], &mut zim[..cnt * p]);
                for pp in 0..p {
                    let off = (pp * k + ki) * c + ci0;
                    for s in 0..cnt {
                        let re = zre[s * p + pp];
                        let im = zim[s * p + pp];
                        vr[off + s] = re;
                        vi[off + s] = im;
                        if gauss {
                            vd[off + s] = im - re;
                            vs[off + s] = re + im;
                        }
                    }
                }
                ci0 += cnt;
                cnt = 0;
            }
        }
    }
    (vr, vi, vd, vs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct;

    fn tol(want: &Tensor4) -> f32 {
        2e-3 * want.max_abs().max(1.0)
    }

    #[test]
    fn plan_matches_direct_all_methods() {
        let x = Tensor4::random([2, 3, 13, 12], 810);
        let w = Tensor4::random([4, 3, 3, 3], 811);
        let want = direct::naive(&x, &w);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 4 },
            ConvAlgorithm::GaussFft { m: 4 },
        ] {
            let mut plan = LayerPlan::new(algo, &w, 13, 12, 1);
            let got = plan.run(&x, None);
            assert!(
                got.max_abs_diff(&want) < tol(&want),
                "{}: {}",
                algo.name(),
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        let x = Tensor4::random([3, 4, 17, 15], 820);
        let w = Tensor4::random([5, 4, 3, 3], 821);
        let pool = ThreadPool::new(4);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 6 },
            ConvAlgorithm::GaussFft { m: 6 },
        ] {
            let mut serial = LayerPlan::new(algo, &w, 17, 15, 1);
            let mut par = LayerPlan::new(algo, &w, 17, 15, 4);
            let a = serial.run(&x, None);
            let b = par.run(&x, Some(&pool));
            assert_eq!(a.shape, b.shape);
            // shard boundaries never change per-tile arithmetic
            assert!(a.max_abs_diff(&b) < 1e-6, "{}", algo.name());
        }
    }

    #[test]
    fn plan_reused_across_batch_sizes() {
        let w = Tensor4::random([2, 2, 3, 3], 830);
        let mut plan = LayerPlan::new(ConvAlgorithm::RegularFft { m: 4 }, &w, 10, 10, 1);
        for (b, seed) in [(1usize, 840u64), (4, 841), (2, 842)] {
            let x = Tensor4::random([b, 2, 10, 10], seed);
            let want = direct::naive(&x, &w);
            let got = plan.run(&x, None);
            assert!(got.max_abs_diff(&want) < tol(&want), "b={b}");
        }
    }

    #[test]
    fn hot_path_allocation_free_after_first_batch() {
        let w = Tensor4::random([3, 2, 3, 3], 850);
        let pool = ThreadPool::new(2);
        let mut plan = LayerPlan::new(ConvAlgorithm::GaussFft { m: 4 }, &w, 12, 12, 2);
        let x1 = Tensor4::random([2, 2, 12, 12], 851);
        let x2 = Tensor4::random([2, 2, 12, 12], 852);
        let o1 = plan.run(&x1, Some(&pool));
        let stamp = plan.arena_stamp();
        let o2 = plan.run(&x2, Some(&pool));
        assert_eq!(stamp, plan.arena_stamp(), "arenas reallocated on hot path");
        for (x, o) in [(&x1, &o1), (&x2, &o2)] {
            let want = direct::naive(x, &w);
            assert!(o.max_abs_diff(&want) < tol(&want));
        }
    }

    #[test]
    fn explicit_fused_and_staged_match_direct() {
        let x = Tensor4::random([2, 3, 13, 12], 880);
        let w = Tensor4::random([4, 3, 3, 3], 881);
        let want = direct::naive(&x, &w);
        let pool = ThreadPool::new(3);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 4 },
            ConvAlgorithm::GaussFft { m: 4 },
        ] {
            for exec in [ExecPolicy::Staged, ExecPolicy::Fused] {
                let opts = PlanOptions {
                    exec,
                    ..PlanOptions::default()
                };
                let mut plan = LayerPlan::with_options(algo, &w, 13, 12, 3, opts);
                let want_mode = match exec {
                    ExecPolicy::Fused => ExecMode::Fused,
                    _ => ExecMode::Staged,
                };
                assert_eq!(plan.exec_mode(), want_mode);
                let got = plan.run(&x, Some(&pool));
                assert!(
                    got.max_abs_diff(&want) < tol(&want),
                    "{} {:?}",
                    algo.name(),
                    exec
                );
            }
        }
    }

    #[test]
    fn auto_falls_back_to_staged_when_panel_does_not_fit() {
        let w = Tensor4::random([4, 3, 3, 3], 882);
        // a budget too small for even MIN_PB tiles forces the staged mode
        let opts = PlanOptions {
            exec: ExecPolicy::Auto,
            fused_budget: 64,
            ..PlanOptions::default()
        };
        let plan = LayerPlan::with_options(ConvAlgorithm::Winograd { m: 4 }, &w, 13, 12, 2, opts);
        assert_eq!(plan.exec_mode(), ExecMode::Staged);
        // while the default budget fuses this small layer
        let plan = LayerPlan::new(ConvAlgorithm::Winograd { m: 4 }, &w, 13, 12, 2);
        assert_eq!(plan.exec_mode(), ExecMode::Fused);
        assert!(plan.panel_tiles() >= 8);
    }

    #[test]
    fn trim_frees_arenas_and_rerun_is_correct() {
        let x = Tensor4::random([2, 2, 12, 12], 883);
        let w = Tensor4::random([3, 2, 3, 3], 884);
        let want = direct::naive(&x, &w);
        for exec in [ExecPolicy::Staged, ExecPolicy::Fused] {
            let opts = PlanOptions {
                exec,
                ..PlanOptions::default()
            };
            let mut plan =
                LayerPlan::with_options(ConvAlgorithm::GaussFft { m: 4 }, &w, 12, 12, 2, opts);
            let a = plan.run(&x, None);
            assert!(plan.arena_bytes() > 0, "{exec:?}: scratch grew");
            let resident_before = plan.resident_bytes();
            plan.trim();
            assert_eq!(plan.arena_bytes(), 0, "{exec:?}: trim freed scratch");
            assert!(plan.resident_bytes() < resident_before);
            let b = plan.run(&x, None);
            assert!(a.max_abs_diff(&want) < tol(&want));
            assert_eq!(
                a.max_abs_diff(&b),
                0.0,
                "{exec:?}: trim changed the arithmetic"
            );
        }
    }

    #[test]
    fn one_plan_serves_both_modes_and_trims_independently() {
        let x = Tensor4::random([2, 3, 13, 12], 890);
        let w = Tensor4::random([4, 3, 3, 3], 891);
        let want = direct::naive(&x, &w);
        let pool = ThreadPool::new(2);
        for algo in [
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 4 },
            ConvAlgorithm::GaussFft { m: 4 },
        ] {
            let mut plan = LayerPlan::new(algo, &w, 13, 12, 2);
            assert!(plan.can_fuse(), "{}: small layer must fuse", algo.name());
            let mut a = Tensor4::zeros(plan.output_shape(2));
            let mut b = Tensor4::zeros(plan.output_shape(2));
            plan.run_with_mode(&x, &mut a, Some(&pool), ExecMode::Staged);
            plan.run_with_mode(&x, &mut b, Some(&pool), ExecMode::Fused);
            assert!(a.max_abs_diff(&want) < tol(&want), "{}", algo.name());
            assert!(b.max_abs_diff(&want) < tol(&want), "{}", algo.name());
            // both variants' scratch coexist on the one plan
            assert!(plan.staged_arena_bytes() > 0, "{}", algo.name());
            assert!(plan.fused_arena_bytes() > 0, "{}", algo.name());
            // trims are independent: dropping one variant's scratch leaves
            // the other's untouched (Gauss shared buffers aside)
            let fused_bytes = plan.fused_arena_bytes();
            plan.trim_staged();
            assert_eq!(plan.staged_arena_bytes(), 0);
            assert_eq!(plan.fused_arena_bytes(), fused_bytes);
            plan.trim_fused();
            assert_eq!(plan.arena_bytes(), 0);
            // the kernel transform survived both trims: rerun is bitwise
            let mut c2 = Tensor4::zeros(plan.output_shape(2));
            plan.run_with_mode(&x, &mut c2, Some(&pool), ExecMode::Fused);
            assert_eq!(b.max_abs_diff(&c2), 0.0, "{}", algo.name());
        }
    }

    #[test]
    fn set_exec_mode_repins_default() {
        let x = Tensor4::random([1, 3, 13, 12], 892);
        let w = Tensor4::random([4, 3, 3, 3], 893);
        let opts = PlanOptions {
            exec: ExecPolicy::Staged,
            ..PlanOptions::default()
        };
        let mut plan =
            LayerPlan::with_options(ConvAlgorithm::RegularFft { m: 4 }, &w, 13, 12, 1, opts);
        assert_eq!(plan.exec_mode(), ExecMode::Staged);
        assert!(plan.can_fuse(), "staged-pinned plan keeps fused capability");
        plan.set_exec_mode(ExecMode::Fused);
        assert_eq!(plan.exec_mode(), ExecMode::Fused);
        let got = plan.run(&x, None); // default path now runs fused
        assert!(plan.fused_arena_bytes() > 0);
        assert_eq!(plan.staged_arena_bytes(), 0);
        let want = direct::naive(&x, &w);
        assert!(got.max_abs_diff(&want) < tol(&want));
    }

    #[test]
    #[should_panic(expected = "no panel fits")]
    fn fused_mode_rejected_when_infeasible() {
        let x = Tensor4::random([1, 3, 13, 12], 894);
        let w = Tensor4::random([4, 3, 3, 3], 895);
        let opts = PlanOptions {
            exec: ExecPolicy::Auto,
            fused_budget: 64,
            ..PlanOptions::default()
        };
        let mut plan =
            LayerPlan::with_options(ConvAlgorithm::Winograd { m: 4 }, &w, 13, 12, 1, opts);
        assert!(!plan.can_fuse());
        let mut out = Tensor4::zeros(plan.output_shape(1));
        plan.run_with_mode(&x, &mut out, None, ExecMode::Fused);
    }

    #[test]
    fn fused_panel_tiles_scales_with_budget_and_planes() {
        // winograd m=4: p=36, one U and one Z plane
        let per_tile = 4 * 36 * (3 + 4);
        assert_eq!(fused_panel_tiles(36, 3, 4, false, false, 10 * per_tile), 10);
        // complex planes double the footprint
        assert!(
            fused_panel_tiles(36, 3, 4, true, false, 10 * per_tile) < 10
        );
        // big channels: fewer than MIN_PB tiles fit a 1MB budget, so Auto
        // falls back to the staged pipeline for this regime
        assert_eq!(fused_panel_tiles(40, 512, 512, true, false, 1 << 20), 3);
    }

    #[test]
    fn fingerprint_distinguishes_weights() {
        let a = Tensor4::random([2, 2, 3, 3], 860);
        let mut b = a.clone();
        b.data[7] += 1e-3;
        assert_ne!(weights_fingerprint(&a), weights_fingerprint(&b));
        assert_eq!(weights_fingerprint(&a), weights_fingerprint(&a.clone()));
    }

    #[test]
    fn run_cached_rebuilds_only_when_stale() {
        let x = Tensor4::random([1, 2, 9, 9], 870);
        let w1 = Tensor4::random([2, 2, 3, 3], 871);
        let w2 = Tensor4::random([2, 2, 3, 3], 872);
        let mut cache = None;
        let algo = ConvAlgorithm::Winograd { m: 3 };
        let got1 = run_cached(algo, &x, &w1, &mut cache, None);
        let fp1 = cache.as_ref().unwrap().weights_fp;
        let _ = run_cached(algo, &x, &w1, &mut cache, None);
        assert_eq!(fp1, cache.as_ref().unwrap().weights_fp, "no rebuild");
        let got2 = run_cached(algo, &x, &w2, &mut cache, None);
        assert_ne!(fp1, cache.as_ref().unwrap().weights_fp, "rebuilt");
        assert!(got1.max_abs_diff(&direct::naive(&x, &w1)) < 1e-3);
        assert!(got2.max_abs_diff(&direct::naive(&x, &w2)) < 1e-3);
    }
}
