//! The native convolution engine: every algorithm the paper benchmarks,
//! over the in-repo substrates (Winograd matrices, FFT plans, blocked
//! GEMMs), sharing one tiling/transform/GEMM/inverse pipeline.

pub mod batch_wino;
pub mod direct;
pub mod engine;
pub mod fft_conv;
pub mod gemm;
pub mod tensor;
pub mod tiles;
pub mod winograd;

pub use engine::{ExecMode, ExecPolicy, LayerPlan, PlanOptions};
pub use fft_conv::FftVariant;
pub use tensor::Tensor4;
pub use tiles::TileGrid;

/// A convolution layer problem: x (B,C,H,W) * w (K,C,r,r), valid, unit
/// stride (the layers the paper benchmarks; strided layers like AlexNet-1
/// are excluded there too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvProblem {
    pub batch: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub r: usize,
}

impl ConvProblem {
    pub fn out_h(&self) -> usize {
        self.h - self.r + 1
    }

    pub fn out_w(&self) -> usize {
        self.w - self.r + 1
    }

    pub fn input_shape(&self) -> [usize; 4] {
        [self.batch, self.c_in, self.h, self.w]
    }

    pub fn weight_shape(&self) -> [usize; 4] {
        [self.c_out, self.c_in, self.r, self.r]
    }

    pub fn output_shape(&self) -> [usize; 4] {
        [self.batch, self.c_out, self.out_h(), self.out_w()]
    }

    /// FLOPs of the direct algorithm (2 ops per MAC) — the paper's
    /// baseline work measure.
    pub fn direct_flops(&self) -> usize {
        2 * self.batch * self.c_out * self.c_in * self.out_h() * self.out_w() * self.r * self.r
    }
}

/// The algorithms under study (Fig. 1's five bars, minus the vendor
/// libraries we substitute per DESIGN.md §3).  `Hash` so the scheduler's
/// persistent plan cache can key on the algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvAlgorithm {
    /// Textbook direct convolution (correctness oracle).
    Direct,
    /// Direct convolution via im2col + GEMM (optimized-direct comparator).
    Im2col,
    /// Winograd F(m^2, r^2).
    Winograd { m: usize },
    /// Regular-FFT 𝔉(m^2, r^2).
    RegularFft { m: usize },
    /// Gauss-FFT 𝔊(m^2, r^2).
    GaussFft { m: usize },
}

impl ConvAlgorithm {
    pub fn name(&self) -> String {
        match self {
            ConvAlgorithm::Direct => "direct".into(),
            ConvAlgorithm::Im2col => "im2col".into(),
            ConvAlgorithm::Winograd { m } => format!("winograd(m={m})"),
            ConvAlgorithm::RegularFft { m } => format!("regular_fft(m={m})"),
            ConvAlgorithm::GaussFft { m } => format!("gauss_fft(m={m})"),
        }
    }

    /// Tile size parameter, if the algorithm is tiled.
    pub fn tile_m(&self) -> Option<usize> {
        match self {
            ConvAlgorithm::Winograd { m }
            | ConvAlgorithm::RegularFft { m }
            | ConvAlgorithm::GaussFft { m } => Some(*m),
            _ => None,
        }
    }
}

/// Execute `algo` on the problem's tensors.
pub fn run(algo: ConvAlgorithm, x: &Tensor4, w: &Tensor4) -> Tensor4 {
    match algo {
        ConvAlgorithm::Direct => direct::naive(x, w),
        ConvAlgorithm::Im2col => direct::im2col(x, w),
        ConvAlgorithm::Winograd { m } => winograd::run(x, w, m),
        ConvAlgorithm::RegularFft { m } => fft_conv::run_regular(x, w, m),
        ConvAlgorithm::GaussFft { m } => fft_conv::run_gauss(x, w, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_shapes() {
        let p = ConvProblem {
            batch: 2,
            c_in: 3,
            c_out: 4,
            h: 14,
            w: 12,
            r: 3,
        };
        assert_eq!(p.output_shape(), [2, 4, 12, 10]);
        assert_eq!(p.direct_flops(), 2 * 2 * 4 * 3 * 12 * 10 * 9);
    }

    #[test]
    fn dispatch_all_algorithms_agree() {
        let p = ConvProblem {
            batch: 1,
            c_in: 3,
            c_out: 2,
            h: 12,
            w: 12,
            r: 3,
        };
        let x = Tensor4::random(p.input_shape(), 1);
        let w = Tensor4::random(p.weight_shape(), 2);
        let want = run(ConvAlgorithm::Direct, &x, &w);
        for algo in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 6 },
            ConvAlgorithm::GaussFft { m: 6 },
        ] {
            let got = run(algo, &x, &w);
            assert_eq!(got.shape, want.shape);
            assert!(
                got.max_abs_diff(&want) < 2e-3 * want.max_abs().max(1.0),
                "{}",
                algo.name()
            );
        }
    }

    #[test]
    fn names_stable() {
        assert_eq!(ConvAlgorithm::Winograd { m: 4 }.name(), "winograd(m=4)");
        assert_eq!(ConvAlgorithm::RegularFft { m: 9 }.tile_m(), Some(9));
        assert_eq!(ConvAlgorithm::Direct.tile_m(), None);
    }
}
