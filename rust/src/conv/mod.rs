//! The native convolution engine: every algorithm the paper benchmarks,
//! over the in-repo substrates (Winograd matrices, FFT plans, blocked
//! GEMMs), sharing one tiling/transform/GEMM/inverse pipeline.

pub mod batch_wino;
pub mod direct;
pub mod engine;
pub mod fft_conv;
pub mod gemm;
pub mod tensor;
pub mod tiles;
pub mod winograd;

pub use engine::{ExecMode, ExecPolicy, LayerPlan, PlanOptions};
pub use fft_conv::FftVariant;
pub use tensor::Tensor4;
pub use tiles::TileGrid;

/// A convolution layer problem: x (B,C,H,W) * w (K,C,r,r) with symmetric
/// zero-padding `pad` and square stride `stride`.
///
/// `stride == 1, pad == 0` is the valid unit-stride convolution the paper
/// benchmarks; VGG's pad=1 layers and AlexNet's strided layer 1 are
/// expressed explicitly instead of being pre-baked into spatial sizes.
/// The tiled transform algorithms (Winograd/FFT) support any `pad` but
/// require `stride == 1`; strided problems run through the direct,
/// im2col, and 1x1-GEMM paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvProblem {
    pub batch: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub r: usize,
    /// square output stride (>= 1)
    pub stride: usize,
    /// symmetric zero-padding on every spatial edge
    pub pad: usize,
}

impl ConvProblem {
    /// Unit-stride, unpadded problem (the paper's benchmark geometry).
    pub const fn unit(batch: usize, c_in: usize, c_out: usize, h: usize, w: usize, r: usize) -> ConvProblem {
        ConvProblem {
            batch,
            c_in,
            c_out,
            h,
            w,
            r,
            stride: 1,
            pad: 0,
        }
    }

    /// Fully general problem with explicit stride and padding.
    pub const fn with_geometry(
        batch: usize,
        c_in: usize,
        c_out: usize,
        h: usize,
        w: usize,
        r: usize,
        stride: usize,
        pad: usize,
    ) -> ConvProblem {
        ConvProblem {
            batch,
            c_in,
            c_out,
            h,
            w,
            r,
            stride,
            pad,
        }
    }

    /// True when the padded image covers the kernel and the stride is
    /// positive — the geometry precondition every execution path assumes.
    pub fn geometry_valid(&self) -> bool {
        self.stride >= 1 && self.h + 2 * self.pad >= self.r && self.w + 2 * self.pad >= self.r
    }

    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.r) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.r) / self.stride + 1
    }

    pub fn input_shape(&self) -> [usize; 4] {
        [self.batch, self.c_in, self.h, self.w]
    }

    pub fn weight_shape(&self) -> [usize; 4] {
        [self.c_out, self.c_in, self.r, self.r]
    }

    pub fn output_shape(&self) -> [usize; 4] {
        [self.batch, self.c_out, self.out_h(), self.out_w()]
    }

    /// FLOPs of the direct algorithm (2 ops per MAC) — the paper's
    /// baseline work measure.  Stride shrinks the output plane, so the
    /// count falls with `stride^2`; padding grows it.
    pub fn direct_flops(&self) -> usize {
        2 * self.batch * self.c_out * self.c_in * self.out_h() * self.out_w() * self.r * self.r
    }

    /// DRAM bytes of one pass assuming no reuse beyond the caches:
    /// input read + weights read + output write (f32).  The roofline
    /// estimators for the non-tiled paths build on this.
    pub fn io_bytes(&self) -> usize {
        4 * (self.batch * self.c_in * self.h * self.w
            + self.c_out * self.c_in * self.r * self.r
            + self.batch * self.c_out * self.out_h() * self.out_w())
    }
}

/// The algorithms under study (Fig. 1's five bars, minus the vendor
/// libraries we substitute per DESIGN.md §3), plus the 1x1 fast path the
/// whole-network graphs need.  `Hash` so the scheduler's persistent plan
/// cache can key on the algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvAlgorithm {
    /// Textbook direct convolution (correctness oracle).
    Direct,
    /// Direct convolution via im2col + GEMM (optimized-direct comparator).
    Im2col,
    /// 1x1 ("pointwise") convolution as a per-pixel GEMM — no tile
    /// transforms, no patch materialization at unit stride: the image is
    /// already the (C x HW) operand.
    Gemm1x1,
    /// Winograd F(m^2, r^2).
    Winograd { m: usize },
    /// Regular-FFT 𝔉(m^2, r^2).
    RegularFft { m: usize },
    /// Gauss-FFT 𝔊(m^2, r^2).
    GaussFft { m: usize },
}

impl ConvAlgorithm {
    pub fn name(&self) -> String {
        match self {
            ConvAlgorithm::Direct => "direct".into(),
            ConvAlgorithm::Im2col => "im2col".into(),
            ConvAlgorithm::Gemm1x1 => "gemm_1x1".into(),
            ConvAlgorithm::Winograd { m } => format!("winograd(m={m})"),
            ConvAlgorithm::RegularFft { m } => format!("regular_fft(m={m})"),
            ConvAlgorithm::GaussFft { m } => format!("gauss_fft(m={m})"),
        }
    }

    /// Tile size parameter, if the algorithm is tiled.
    pub fn tile_m(&self) -> Option<usize> {
        match self {
            ConvAlgorithm::Winograd { m }
            | ConvAlgorithm::RegularFft { m }
            | ConvAlgorithm::GaussFft { m } => Some(*m),
            _ => None,
        }
    }

    /// Can this algorithm execute the problem's geometry?  The tiled
    /// transforms require unit stride; `Gemm1x1` requires r == 1.
    pub fn supports(&self, p: &ConvProblem) -> bool {
        if !p.geometry_valid() {
            return false;
        }
        match self {
            ConvAlgorithm::Direct | ConvAlgorithm::Im2col => true,
            ConvAlgorithm::Gemm1x1 => p.r == 1,
            _ => p.stride == 1,
        }
    }
}

/// Execute `algo` on the problem's tensors (unit stride, no padding —
/// the paper's benchmark geometry).  See [`run_problem`] for explicit
/// stride/padding.
pub fn run(algo: ConvAlgorithm, x: &Tensor4, w: &Tensor4) -> Tensor4 {
    let [b, c, h, wd] = x.shape;
    let [k, _, r, _] = w.shape;
    run_problem(algo, &ConvProblem::unit(b, c, k, h, wd, r), x, w)
}

/// Execute `algo` on a fully specified problem (stride + padding).
pub fn run_problem(algo: ConvAlgorithm, p: &ConvProblem, x: &Tensor4, w: &Tensor4) -> Tensor4 {
    assert_eq!(x.shape, p.input_shape(), "input/problem mismatch");
    assert_eq!(w.shape, p.weight_shape(), "weight/problem mismatch");
    assert!(
        algo.supports(p),
        "{} cannot run stride={} pad={} r={}",
        algo.name(),
        p.stride,
        p.pad,
        p.r
    );
    match algo {
        ConvAlgorithm::Direct => direct::reference(p, x, w),
        ConvAlgorithm::Im2col => direct::im2col_problem(p, x, w),
        ConvAlgorithm::Gemm1x1 => direct::conv1x1(p, x, w),
        // unpadded tiled problems keep the lightweight one-shot paths;
        // padding routes through the engine plan (the gather stage
        // materializes the halo)
        ConvAlgorithm::Winograd { m } if p.pad == 0 => winograd::run(x, w, m),
        ConvAlgorithm::RegularFft { m } if p.pad == 0 => fft_conv::run_regular(x, w, m),
        ConvAlgorithm::GaussFft { m } if p.pad == 0 => fft_conv::run_gauss(x, w, m),
        tiled => tiled_problem(tiled, p, x, w),
    }
}

/// One-shot tiled execution honoring the problem's padding (builds a
/// throwaway plan; serving callers use the scheduler's plan cache).
fn tiled_problem(algo: ConvAlgorithm, p: &ConvProblem, x: &Tensor4, w: &Tensor4) -> Tensor4 {
    let mut plan = LayerPlan::with_options(
        algo,
        w,
        p.h,
        p.w,
        1,
        PlanOptions {
            pad: p.pad,
            ..PlanOptions::default()
        },
    );
    plan.run(x, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_shapes() {
        let p = ConvProblem::unit(2, 3, 4, 14, 12, 3);
        assert_eq!(p.output_shape(), [2, 4, 12, 10]);
        assert_eq!(p.direct_flops(), 2 * 2 * 4 * 3 * 12 * 10 * 9);
    }

    #[test]
    fn problem_shapes_with_stride_and_pad() {
        // AlexNet-1 geometry: 227 -> (227 - 11)/4 + 1 = 55
        let p = ConvProblem::with_geometry(1, 3, 64, 227, 227, 11, 4, 0);
        assert_eq!(p.output_shape(), [1, 64, 55, 55]);
        // VGG geometry: pad 1 keeps the feature map size
        let p = ConvProblem::with_geometry(2, 64, 64, 224, 224, 3, 1, 1);
        assert_eq!(p.output_shape(), [2, 64, 224, 224]);
        // strided + padded
        let p = ConvProblem::with_geometry(1, 2, 2, 9, 9, 3, 2, 1);
        assert_eq!(p.out_h(), 5);
        assert!(p.geometry_valid());
        // degenerate: kernel larger than padded image
        let bad = ConvProblem::with_geometry(1, 1, 1, 2, 2, 5, 1, 1);
        assert!(!bad.geometry_valid());
    }

    #[test]
    fn dispatch_all_algorithms_agree() {
        let p = ConvProblem::unit(1, 3, 2, 12, 12, 3);
        let x = Tensor4::random(p.input_shape(), 1);
        let w = Tensor4::random(p.weight_shape(), 2);
        let want = run(ConvAlgorithm::Direct, &x, &w);
        for algo in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 6 },
            ConvAlgorithm::GaussFft { m: 6 },
        ] {
            let got = run(algo, &x, &w);
            assert_eq!(got.shape, want.shape);
            assert!(
                got.max_abs_diff(&want) < 2e-3 * want.max_abs().max(1.0),
                "{}",
                algo.name()
            );
        }
    }

    #[test]
    fn padded_dispatch_agrees_with_oracle() {
        let p = ConvProblem::with_geometry(2, 3, 4, 10, 9, 3, 1, 1);
        let x = Tensor4::random(p.input_shape(), 11);
        let w = Tensor4::random(p.weight_shape(), 12);
        let want = run_problem(ConvAlgorithm::Direct, &p, &x, &w);
        assert_eq!(want.shape, p.output_shape());
        for algo in [
            ConvAlgorithm::Im2col,
            ConvAlgorithm::Winograd { m: 4 },
            ConvAlgorithm::RegularFft { m: 4 },
            ConvAlgorithm::GaussFft { m: 4 },
        ] {
            let got = run_problem(algo, &p, &x, &w);
            assert_eq!(got.shape, want.shape, "{}", algo.name());
            assert!(
                got.max_abs_diff(&want) < 2e-3 * want.max_abs().max(1.0),
                "{}",
                algo.name()
            );
        }
    }

    #[test]
    fn supports_matrix() {
        let strided = ConvProblem::with_geometry(1, 2, 2, 8, 8, 3, 2, 0);
        let pointwise = ConvProblem::with_geometry(1, 2, 2, 8, 8, 1, 1, 0);
        assert!(ConvAlgorithm::Direct.supports(&strided));
        assert!(ConvAlgorithm::Im2col.supports(&strided));
        assert!(!ConvAlgorithm::Gemm1x1.supports(&strided)); // r != 1
        assert!(!ConvAlgorithm::Winograd { m: 2 }.supports(&strided));
        assert!(ConvAlgorithm::Gemm1x1.supports(&pointwise));
        assert!(ConvAlgorithm::RegularFft { m: 4 }.supports(&pointwise));
    }

    #[test]
    fn names_stable() {
        assert_eq!(ConvAlgorithm::Winograd { m: 4 }.name(), "winograd(m=4)");
        assert_eq!(ConvAlgorithm::RegularFft { m: 9 }.tile_m(), Some(9));
        assert_eq!(ConvAlgorithm::Direct.tile_m(), None);
        assert_eq!(ConvAlgorithm::Gemm1x1.name(), "gemm_1x1");
    }
}
