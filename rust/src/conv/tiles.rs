//! Overlap-add tiling (§2.2): gather t x t input tiles with stride m and
//! overlap r-1 (implicit zero-padding at the bottom/right edges, plus the
//! problem's own symmetric zero-padding on all four), and scatter the
//! m x m output tiles back.

/// Tiling geometry for one (image, m, r, pad) configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    pub m: usize,
    pub r: usize,
    pub t: usize,
    /// input spatial size (unpadded)
    pub h: usize,
    pub w: usize,
    /// the problem's symmetric zero-padding: tile origins start at -pad
    pub pad: usize,
    /// output spatial size (padded conv)
    pub oh: usize,
    pub ow: usize,
    /// tiles along each axis
    pub nh: usize,
    pub nw: usize,
}

impl TileGrid {
    pub fn new(h: usize, w: usize, m: usize, r: usize) -> TileGrid {
        TileGrid::with_pad(h, w, m, r, 0)
    }

    /// Geometry for a problem with symmetric zero-padding `pad`: the
    /// first tile's origin sits at (-pad, -pad) and the output plane is
    /// (h + 2*pad - r + 1) square-ish — the gather stage materializes the
    /// halo as zeros, so no padded copy of the input ever exists.
    pub fn with_pad(h: usize, w: usize, m: usize, r: usize, pad: usize) -> TileGrid {
        assert!(h + 2 * pad >= r && w + 2 * pad >= r, "image smaller than kernel");
        let t = m + r - 1;
        let oh = h + 2 * pad - r + 1;
        let ow = w + 2 * pad - r + 1;
        let nh = oh.div_ceil(m);
        let nw = ow.div_ceil(m);
        TileGrid {
            m,
            r,
            t,
            h,
            w,
            pad,
            oh,
            ow,
            nh,
            nw,
        }
    }

    /// Tiles per image.
    pub fn tiles(&self) -> usize {
        self.nh * self.nw
    }

    /// Gather tile (ti, tj) of `plane` (h x w) into `out` (t x t),
    /// zero-padding outside the image (both the overlap-add remainder at
    /// the bottom/right and the problem's own pad halo on all sides).
    ///
    /// Fully interior tiles — the overwhelming majority on real layers —
    /// take a branch-free path of `t` unconditional row copies with no
    /// zero-fill at all; only tiles straddling an image edge pay for
    /// padding, and even there only the fringe is memset.
    pub fn gather(&self, plane: &[f32], ti: usize, tj: usize, out: &mut [f32]) {
        debug_assert_eq!(plane.len(), self.h * self.w);
        debug_assert_eq!(out.len(), self.t * self.t);
        let (t, w) = (self.t, self.w);
        let i0 = (ti * self.m) as isize - self.pad as isize;
        let j0 = (tj * self.m) as isize - self.pad as isize;
        if i0 >= 0 && j0 >= 0 && i0 as usize + t <= self.h && j0 as usize + t <= w {
            let (i0, j0) = (i0 as usize, j0 as usize);
            for u in 0..t {
                let row = (i0 + u) * w + j0;
                out[u * t..(u + 1) * t].copy_from_slice(&plane[row..row + t]);
            }
            return;
        }
        // edge tile: copy the in-bounds sub-rectangle row by row, zero
        // the fringe (left/top halo rows and right/bottom remainder)
        let col_lo = (-j0).max(0) as usize; // first in-bounds tile column
        let col_hi = ((w as isize - j0).max(0) as usize).min(t); // one past last
        for u in 0..t {
            let si = i0 + u as isize;
            let dst = &mut out[u * t..(u + 1) * t];
            if si < 0 || si >= self.h as isize || col_lo >= col_hi {
                dst.fill(0.0);
                continue;
            }
            let row = si as usize * w + (j0 + col_lo as isize) as usize;
            dst[..col_lo].fill(0.0);
            dst[col_lo..col_hi].copy_from_slice(&plane[row..row + (col_hi - col_lo)]);
            dst[col_hi..].fill(0.0);
        }
    }

    /// Scatter an m x m output tile (ti, tj) into `plane` (oh x ow),
    /// dropping the zero-pad remainder.
    pub fn scatter(&self, tile: &[f32], ti: usize, tj: usize, plane: &mut [f32]) {
        debug_assert_eq!(plane.len(), self.oh * self.ow);
        self.scatter_rows(tile, ti, tj, 0, plane);
    }

    /// Visit the valid output spans of tile (ti, tj): `f(plane_off,
    /// tile_off, len)` once per in-bounds row, where `plane_off` indexes
    /// the oh x ow output plane and `tile_off` the m x m tile.  This is
    /// the address generator behind [`TileGrid::scatter`], exposed so the
    /// fused pipeline can route the same spans through a shared-output
    /// writer (raw disjoint writes) instead of a `&mut` plane.
    pub fn scatter_spans(&self, ti: usize, tj: usize, mut f: impl FnMut(usize, usize, usize)) {
        let (i0, j0) = (ti * self.m, tj * self.m);
        let count = self.ow.saturating_sub(j0).min(self.m);
        for u in 0..self.m {
            let dst_i = i0 + u;
            if dst_i >= self.oh {
                break;
            }
            f(dst_i * self.ow + j0, u * self.m, count);
        }
    }

    /// Scatter into a row window of the output plane: `dst` covers output
    /// rows `row0 .. row0 + dst.len()/ow`.  This is what lets the inverse
    /// stage hand each worker a disjoint `&mut` sub-slice of the output
    /// tensor (tile-row sharding) instead of the whole plane.
    pub fn scatter_rows(&self, tile: &[f32], ti: usize, tj: usize, row0: usize, dst: &mut [f32]) {
        debug_assert_eq!(tile.len(), self.m * self.m);
        debug_assert_eq!(dst.len() % self.ow, 0);
        let rows = dst.len() / self.ow;
        let (i0, j0) = (ti * self.m, tj * self.m);
        let count = self.ow.saturating_sub(j0).min(self.m);
        for u in 0..self.m {
            let dst_i = i0 + u;
            if dst_i >= self.oh || dst_i >= row0 + rows {
                break;
            }
            if dst_i < row0 {
                continue;
            }
            let local = dst_i - row0;
            let out = &mut dst[local * self.ow + j0..local * self.ow + j0 + count];
            out.copy_from_slice(&tile[u * self.m..u * self.m + count]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn geometry_exact_division() {
        let g = TileGrid::new(14, 14, 4, 3); // oh = 12, 3 tiles of 4
        assert_eq!((g.t, g.oh, g.nh), (6, 12, 3));
    }

    #[test]
    fn geometry_with_remainder() {
        let g = TileGrid::new(13, 13, 4, 3); // oh = 11 -> 3 tiles (4+4+3)
        assert_eq!((g.nh, g.nw), (3, 3));
    }

    #[test]
    fn gather_interior_tile() {
        let g = TileGrid::new(8, 8, 2, 3); // t = 4
        let plane: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut tile = vec![0.0; 16];
        g.gather(&plane, 1, 1, &mut tile);
        // tile origin at (2, 2)
        assert_eq!(tile[0], plane[2 * 8 + 2]);
        assert_eq!(tile[15], plane[5 * 8 + 5]);
    }

    #[test]
    fn gather_edge_tile_zero_pads() {
        let g = TileGrid::new(7, 7, 4, 3); // oh=5, nh=2, second tile needs rows 4..10
        let plane = vec![1.0f32; 49];
        let mut tile = vec![9.0; 36];
        g.gather(&plane, 1, 1, &mut tile);
        // rows 0..3 have data cols 0..3, rest zero
        assert_eq!(tile[0], 1.0);
        assert_eq!(tile[5], 0.0); // col 4+5=9 >= 7 -> padded? row0 col5: j0=4,col idx 5 -> 9 > w
        assert_eq!(tile[30], 0.0); // row 6 -> i=10 >= 7
    }

    #[test]
    fn scatter_roundtrip_covers_output() {
        let g = TileGrid::new(13, 11, 4, 3);
        let mut rng = Rng::new(3);
        // build per-tile data whose value encodes output coordinates
        let mut plane = vec![-1.0f32; g.oh * g.ow];
        for ti in 0..g.nh {
            for tj in 0..g.nw {
                let mut tile = vec![0.0f32; g.m * g.m];
                for u in 0..g.m {
                    for v in 0..g.m {
                        let (i, j) = (ti * g.m + u, tj * g.m + v);
                        tile[u * g.m + v] = if i < g.oh && j < g.ow {
                            (i * g.ow + j) as f32
                        } else {
                            rng.next_f32_signed() // garbage that must be dropped
                        };
                    }
                }
                g.scatter(&tile, ti, tj, &mut plane);
            }
        }
        for (i, v) in plane.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn scatter_spans_equals_scatter() {
        let g = TileGrid::new(13, 11, 4, 3); // remainder tiles on both axes
        let mut rng = Rng::new(23);
        let mut want = vec![0.0f32; g.oh * g.ow];
        let mut got = vec![0.0f32; g.oh * g.ow];
        for ti in 0..g.nh {
            for tj in 0..g.nw {
                let tile = rng.vec_f32(g.m * g.m);
                g.scatter(&tile, ti, tj, &mut want);
                g.scatter_spans(ti, tj, |dst, src, len| {
                    got[dst..dst + len].copy_from_slice(&tile[src..src + len]);
                });
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn scatter_rows_matches_full_scatter() {
        let g = TileGrid::new(13, 11, 4, 3); // oh=11, ow=9, nh=3
        let mut rng = Rng::new(17);
        let tiles: Vec<Vec<f32>> = (0..g.nh * g.nw).map(|_| rng.vec_f32(g.m * g.m)).collect();
        // reference: whole-plane scatter
        let mut want = vec![0.0f32; g.oh * g.ow];
        for ti in 0..g.nh {
            for tj in 0..g.nw {
                g.scatter(&tiles[ti * g.nw + tj], ti, tj, &mut want);
            }
        }
        // row-windowed: one window per tile row, clipped at oh
        let mut got = vec![0.0f32; g.oh * g.ow];
        for ti in 0..g.nh {
            let row0 = ti * g.m;
            let row1 = (row0 + g.m).min(g.oh);
            let window = &mut got[row0 * g.ow..row1 * g.ow];
            for tj in 0..g.nw {
                g.scatter_rows(&tiles[ti * g.nw + tj], ti, tj, row0, window);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn padded_geometry_and_halo_gather() {
        // 8x8 image, pad 1, r=3: output stays 8x8, first tile origin at -1
        let g = TileGrid::with_pad(8, 8, 4, 3, 1);
        assert_eq!((g.oh, g.ow, g.nh, g.nw), (8, 8, 2, 2));
        let plane: Vec<f32> = (0..64).map(|i| i as f32 + 1.0).collect();
        let mut tile = vec![f32::NAN; 36];
        g.gather(&plane, 0, 0, &mut tile);
        // row 0 and column 0 of the tile are the zero halo
        for v in 0..6 {
            assert_eq!(tile[v], 0.0, "halo row, col {v}");
            assert_eq!(tile[v * 6], 0.0, "halo col, row {v}");
        }
        // interior of the tile is the image's top-left corner
        for u in 1..6 {
            for v in 1..6 {
                assert_eq!(tile[u * 6 + v], plane[(u - 1) * 8 + (v - 1)], "({u},{v})");
            }
        }
        // tile (1,1): origin (3,3), fully interior despite the pad
        let mut tile = vec![f32::NAN; 36];
        g.gather(&plane, 1, 1, &mut tile);
        for u in 0..6 {
            for v in 0..6 {
                let (i, j) = (3 + u, 3 + v);
                let want = if i < 8 && j < 8 { plane[i * 8 + j] } else { 0.0 };
                assert_eq!(tile[u * 6 + v], want, "({u},{v})");
            }
        }
    }

    #[test]
    fn padded_gather_then_direct_equals_padded_direct() {
        // correlating gathered tiles of a padded grid reproduces the
        // zero-padded direct convolution
        let (h, w, m, r, pad) = (9, 8, 3, 3, 2);
        let g = TileGrid::with_pad(h, w, m, r, pad);
        let mut rng = Rng::new(12);
        let plane = rng.vec_f32(h * w);
        let kern = rng.vec_f32(r * r);
        // padded direct reference
        let mut want = vec![0.0f32; g.oh * g.ow];
        for i in 0..g.oh {
            for j in 0..g.ow {
                let mut s = 0.0;
                for u in 0..r {
                    for v in 0..r {
                        let (si, sj) = (i + u, j + v);
                        if si < pad || sj < pad || si >= h + pad || sj >= w + pad {
                            continue;
                        }
                        s += plane[(si - pad) * w + (sj - pad)] * kern[u * r + v];
                    }
                }
                want[i * g.ow + j] = s;
            }
        }
        // tile-wise
        let mut got = vec![0.0f32; g.oh * g.ow];
        let mut tile = vec![0.0f32; g.t * g.t];
        let mut otile = vec![0.0f32; g.m * g.m];
        for ti in 0..g.nh {
            for tj in 0..g.nw {
                g.gather(&plane, ti, tj, &mut tile);
                for u in 0..m {
                    for v in 0..m {
                        let mut s = 0.0;
                        for a in 0..r {
                            for b in 0..r {
                                s += tile[(u + a) * g.t + v + b] * kern[a * r + b];
                            }
                        }
                        otile[u * m + v] = s;
                    }
                }
                g.scatter(&otile, ti, tj, &mut got);
            }
        }
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "pixel {i}: {a} vs {b}");
        }
    }

    #[test]
    fn gather_then_direct_equals_whole_image() {
        // correlating each gathered tile reproduces the tile of the output
        let (h, w, m, r) = (10, 9, 3, 3);
        let g = TileGrid::new(h, w, m, r);
        let mut rng = Rng::new(8);
        let plane = rng.vec_f32(h * w);
        let kern = rng.vec_f32(r * r);
        // full direct
        let mut want = vec![0.0f32; g.oh * g.ow];
        for i in 0..g.oh {
            for j in 0..g.ow {
                let mut s = 0.0;
                for u in 0..r {
                    for v in 0..r {
                        s += plane[(i + u) * w + j + v] * kern[u * r + v];
                    }
                }
                want[i * g.ow + j] = s;
            }
        }
        // tile-wise direct
        let mut got = vec![0.0f32; g.oh * g.ow];
        let mut tile = vec![0.0f32; g.t * g.t];
        let mut otile = vec![0.0f32; g.m * g.m];
        for ti in 0..g.nh {
            for tj in 0..g.nw {
                g.gather(&plane, ti, tj, &mut tile);
                for u in 0..m {
                    for v in 0..m {
                        let mut s = 0.0;
                        for a in 0..r {
                            for b in 0..r {
                                s += tile[(u + a) * g.t + v + b] * kern[a * r + b];
                            }
                        }
                        otile[u * m + v] = s;
                    }
                }
                g.scatter(&otile, ti, tj, &mut got);
            }
        }
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
