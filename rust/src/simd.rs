//! Runtime ISA detection and dispatch for the explicit SIMD micro-kernels.
//!
//! The paper's kernels are hand-vectorized AVX-512 (§4); this crate keeps a
//! scalar reference path compiled on every target and adds AVX2+FMA and
//! AVX-512F variants of the panel GEMMs (`conv::gemm`).  An [`Isa`] value
//! names one of those kernel sets.  Detection runs once per process
//! ([`Isa::detect_max`]); plans resolve their kernel set once at
//! construction ([`Isa::resolved`] honours the `FFTCONV_FORCE_ISA`
//! environment override, clamped to what the host supports) so the
//! per-batch hot path stays branch-free.
//!
//! Ordering is total and meaningful: `Scalar < Avx2 < Avx512`, so clamping
//! a requested ISA to the host's capability is `request.min(detected)` —
//! a safe-code-constructed [`Isa`] can never select an illegal instruction.

use std::sync::OnceLock;

pub mod transpose;

/// One compiled kernel set. Ordered by capability: `Scalar < Avx2 < Avx512`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Isa {
    /// Portable Rust loops — always compiled, always correct.
    Scalar,
    /// AVX2 + FMA: 8-lane f32, 6x16 register blocking.
    Avx2,
    /// AVX-512F: 16-lane f32, 8x32 register blocking.
    Avx512,
}

/// Environment variable that forces a kernel set (`scalar` | `avx2` |
/// `avx512`).  Requests above the host's capability are clamped down, so
/// `FFTCONV_FORCE_ISA=avx512` on an AVX2-only host runs AVX2, not UB.
pub const FORCE_ISA_ENV: &str = "FFTCONV_FORCE_ISA";

impl Isa {
    /// Short stable name, used in logs / BENCH_hotpaths.json.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    /// Parse a [`FORCE_ISA_ENV`] value. Unknown strings yield `None`.
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            _ => None,
        }
    }

    /// The widest kernel set this host can execute. Detected once per
    /// process with `is_x86_feature_detected!`; non-x86 targets are Scalar.
    pub fn detect_max() -> Isa {
        static DETECTED: OnceLock<Isa> = OnceLock::new();
        *DETECTED.get_or_init(detect_max_uncached)
    }

    /// Clamp this (possibly user-requested) ISA to the host's capability.
    pub fn clamp_to_host(self) -> Isa {
        self.min(Isa::detect_max())
    }

    /// The process-wide default kernel set: the [`FORCE_ISA_ENV`] override
    /// if set and parseable (clamped to the host), else the detected
    /// maximum.  Read once; plans built later all agree.
    pub fn resolved() -> Isa {
        static RESOLVED: OnceLock<Isa> = OnceLock::new();
        *RESOLVED.get_or_init(|| match std::env::var(FORCE_ISA_ENV) {
            Ok(v) => match Isa::parse(&v) {
                Some(isa) => isa.clamp_to_host(),
                None => Isa::detect_max(),
            },
            Err(_) => Isa::detect_max(),
        })
    }

    /// Every kernel set the host can execute, narrowest first.  The
    /// equivalence suite iterates this so it is green on any x86-64 host
    /// (and degenerates to `[Scalar]` elsewhere).
    pub fn available() -> Vec<Isa> {
        let max = Isa::detect_max();
        [Isa::Scalar, Isa::Avx2, Isa::Avx512]
            .into_iter()
            .filter(|isa| *isa <= max)
            .collect()
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_max_uncached() -> Isa {
    if is_x86_feature_detected!("avx512f") {
        Isa::Avx512
    } else if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_max_uncached() -> Isa {
    Isa::Scalar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_by_capability() {
        assert!(Isa::Scalar < Isa::Avx2);
        assert!(Isa::Avx2 < Isa::Avx512);
    }

    #[test]
    fn parse_round_trips_names() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("AVX512F"), Some(Isa::Avx512));
        assert_eq!(Isa::parse("neon"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn clamp_never_exceeds_host() {
        let max = Isa::detect_max();
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512] {
            assert!(isa.clamp_to_host() <= max);
        }
        assert_eq!(Isa::Scalar.clamp_to_host(), Isa::Scalar);
    }

    #[test]
    fn available_starts_scalar_and_is_sorted() {
        let avail = Isa::available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*avail.last().unwrap(), Isa::detect_max());
    }

    #[test]
    fn resolved_is_stable_and_executable() {
        let a = Isa::resolved();
        let b = Isa::resolved();
        assert_eq!(a, b);
        assert!(a <= Isa::detect_max());
    }
}
