//! Shared infrastructure: deterministic RNG, a micro-benchmark harness, a
//! minimal JSON reader/writer, a static fork-join thread pool, statistics,
//! and an in-repo property-testing helper.
//!
//! The offline crate registry only carries the `xla` closure, so the usual
//! suspects (serde, criterion, rayon, proptest) are re-implemented here at
//! the scale this repo needs — see DESIGN.md §3 (substitutions).

pub mod aligned;
pub mod bench;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use aligned::AlignedVec;
pub use bench::{bench, BenchResult};
pub use rng::Rng;
