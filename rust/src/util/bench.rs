//! Micro-benchmark harness (criterion substitute, DESIGN.md §3).
//!
//! Adaptive warmup + median-of-N timing, plus report emitters shared by all
//! `rust/benches/*` binaries: aligned markdown tables, CSV files under
//! `bench_out/`, and ASCII line plots for the figure benches.

use std::time::{Duration, Instant};

/// Timing summary for one benchmarked operation.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

/// Benchmark `f`, autoscaling iteration count to ~`budget_ms` total.
///
/// Returns median-of-iters wall clock. `f` should return something cheap
/// to move (use `std::hint::black_box` inside for dead-code safety).
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // one mandatory warmup (page-in, lazy init, branch predictors)
    f();
    // estimate single-shot cost
    let t0 = Instant::now();
    f();
    let single = t0.elapsed().max(Duration::from_nanos(100));

    let budget = Duration::from_millis(budget_ms.max(1));
    let iters = (budget.as_nanos() / single.as_nanos()).clamp(3, 101) as usize;

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    BenchResult {
        name: name.to_string(),
        median,
        mean,
        min: samples[0],
        max: *samples.last().unwrap(),
        iters,
    }
}

/// A rows-and-columns report table with aligned markdown output.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV under `bench_out/<stem>.csv` and print markdown to stdout.
    pub fn emit(&self, stem: &str) {
        println!("{}", self.to_markdown());
        let dir = std::path::Path::new("bench_out");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{stem}.csv"));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("[csv] {}", path.display());
            }
        }
    }
}

/// ASCII scatter/line plot: series of (x, y) with labels — used by the
/// figure benches to sketch the paper's plots in the terminal.
pub fn ascii_plot(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for (_, s) in series {
        pts.extend_from_slice(s);
    }
    if pts.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'o', b'+', b'x', b'#', b'@'];
    for (si, (_, s)) in series.iter().enumerate() {
        for &(x, y) in s {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}\n  y: {y0:.3} .. {y1:.3}\n");
    for row in grid {
        out.push_str("  |");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!(
        "  +{}\n   x: {x0:.2} .. {x1:.2}   ",
        "-".repeat(width)
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("[{}]={} ", marks[si % marks.len()] as char, name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop-ish", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T") && md.contains("| 1 |"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn plot_renders_all_series() {
        let p = ascii_plot(
            "demo",
            &[
                ("up", vec![(0.0, 0.0), (1.0, 1.0)]),
                ("down", vec![(0.0, 1.0), (1.0, 0.0)]),
            ],
            20,
            8,
        );
        assert!(p.contains('*') && p.contains('o'));
    }
}
