//! 64-byte-aligned growable `f32` buffer for GEMM arenas and panels.
//!
//! The SIMD micro-kernels use unaligned load/store intrinsics, so 64-byte
//! alignment is a performance property (no cache-line-split accesses on
//! full vectors when offsets are round), not a correctness requirement —
//! but the arenas and worker panels are exactly the buffers those kernels
//! stream through, so the engine allocates them here and asserts the
//! alignment in debug builds.
//!
//! The semantics mirror how the engine used `Vec<f32>`: grow-only
//! `resize(n)` (never shrinks capacity), zero-filled growth, `Deref` to
//! `[f32]`, and `new()` replaces a trimmed buffer without allocating.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Cache-line alignment for all kernel-visible buffers.
pub const BUF_ALIGN: usize = 64;

/// Grow-only, zero-filled, 64-byte-aligned `f32` buffer.
pub struct AlignedVec {
    ptr: NonNull<f32>,
    len: usize,
    cap: usize,
}

// The buffer owns its allocation and holds plain f32s; sharing &self or
// moving across threads is as safe as for Vec<f32>.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    /// An empty buffer. Does not allocate; the pointer is a 64-byte-aligned
    /// dangling sentinel so `as_ptr()` alignment holds even at len 0.
    pub const fn new() -> AlignedVec {
        AlignedVec {
            // BUF_ALIGN is non-zero, so this invalid-but-well-aligned
            // address is a valid NonNull dangling pointer.
            ptr: unsafe { NonNull::new_unchecked(BUF_ALIGN as *mut f32) },
            len: 0,
            cap: 0,
        }
    }

    /// A zero-filled buffer of `n` elements.
    pub fn zeroed(n: usize) -> AlignedVec {
        let mut v = AlignedVec::new();
        v.resize(n);
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<f32>(), BUF_ALIGN)
            .expect("AlignedVec layout overflow")
    }

    /// Resize to exactly `n` elements. Growth beyond capacity reallocates
    /// (preserving the prefix, zero-filling the rest); shrinking just drops
    /// `len` — capacity is retained, matching the arenas' grow-only use.
    pub fn resize(&mut self, n: usize) {
        if n > self.cap {
            let layout = Self::layout(n);
            // alloc_zeroed gives the zero fill for the grown region free
            let raw = unsafe { alloc_zeroed(layout) } as *mut f32;
            let Some(ptr) = NonNull::new(raw) else {
                handle_alloc_error(layout)
            };
            if self.len > 0 {
                unsafe {
                    std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len)
                };
            }
            if self.cap > 0 {
                unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
            }
            self.ptr = ptr;
            self.cap = n;
        } else if n > self.len {
            // reused capacity may hold stale values from a larger run
            unsafe { std::ptr::write_bytes(self.ptr.as_ptr().add(self.len), 0, n - self.len) };
        }
        self.len = n;
    }

    /// Set every live element to zero.
    pub fn clear_to_zero(&mut self) {
        unsafe { std::ptr::write_bytes(self.ptr.as_ptr(), 0, self.len) };
    }

    pub fn as_ptr(&self) -> *const f32 {
        self.ptr.as_ptr()
    }

    pub fn as_mut_ptr(&mut self) -> *mut f32 {
        self.ptr.as_ptr()
    }

    /// True when the storage satisfies [`BUF_ALIGN`] (always, by
    /// construction — exposed for the engine's debug assertions).
    pub fn is_aligned(&self) -> bool {
        self.ptr.as_ptr() as usize % BUF_ALIGN == 0
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.cap > 0 {
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }
}

impl Default for AlignedVec {
    fn default() -> AlignedVec {
        AlignedVec::new()
    }
}

impl Deref for AlignedVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> AlignedVec {
        let mut v = AlignedVec::zeroed(self.len);
        v.copy_from_slice(self);
        v
    }
}

impl std::fmt::Debug for AlignedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedVec")
            .field("len", &self.len)
            .field("cap", &self.cap)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_aligned_and_unallocated() {
        let v = AlignedVec::new();
        assert!(v.is_empty());
        assert!(v.is_aligned());
        assert_eq!(v.len(), 0);
        assert_eq!(&v[..], &[] as &[f32]);
    }

    #[test]
    fn grow_zero_fills_and_preserves_prefix() {
        let mut v = AlignedVec::zeroed(7);
        assert!(v.iter().all(|&x| x == 0.0));
        for (i, x) in v.iter_mut().enumerate() {
            *x = i as f32 + 1.0;
        }
        v.resize(100);
        assert!(v.is_aligned());
        for i in 0..7 {
            assert_eq!(v[i], i as f32 + 1.0);
        }
        assert!(v[7..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shrink_then_regrow_rezeroes_reused_tail() {
        let mut v = AlignedVec::zeroed(32);
        for x in v.iter_mut() {
            *x = 5.0;
        }
        v.resize(4);
        assert_eq!(v.len(), 4);
        v.resize(32); // within retained capacity
        assert!(v[4..].iter().all(|&x| x == 0.0), "stale tail survived");
        assert!(v[..4].iter().all(|&x| x == 5.0));
    }

    #[test]
    fn alignment_holds_across_many_sizes() {
        for n in [1usize, 3, 15, 16, 17, 63, 64, 65, 1000] {
            let v = AlignedVec::zeroed(n);
            assert!(v.is_aligned(), "n={n}");
            assert_eq!(v.as_ptr() as usize % BUF_ALIGN, 0);
        }
    }

    #[test]
    fn clone_copies_contents() {
        let mut v = AlignedVec::zeroed(10);
        for (i, x) in v.iter_mut().enumerate() {
            *x = i as f32;
        }
        let w = v.clone();
        assert_eq!(&v[..], &w[..]);
        assert!(w.is_aligned());
    }

    #[test]
    fn clear_to_zero_wipes_live_elements() {
        let mut v = AlignedVec::zeroed(9);
        for x in v.iter_mut() {
            *x = 2.5;
        }
        v.clear_to_zero();
        assert!(v.iter().all(|&x| x == 0.0));
    }
}
