//! 64-byte-aligned growable `f32` buffer for GEMM arenas and panels.
//!
//! The SIMD micro-kernels use unaligned load/store intrinsics, so 64-byte
//! alignment is a performance property (no cache-line-split accesses on
//! full vectors when offsets are round), not a correctness requirement —
//! but the arenas and worker panels are exactly the buffers those kernels
//! stream through, so the engine allocates them here and asserts the
//! alignment in debug builds.
//!
//! The semantics mirror how the engine used `Vec<f32>`: grow-only
//! `resize(n)` (never shrinks capacity), zero-filled growth, `Deref` to
//! `[f32]`, and `new()` replaces a trimmed buffer without allocating.

use crate::simd::Isa;
use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Cache-line alignment for all kernel-visible buffers.
pub const BUF_ALIGN: usize = 64;

/// Grow-only, zero-filled, 64-byte-aligned `f32` buffer.
pub struct AlignedVec {
    ptr: NonNull<f32>,
    len: usize,
    cap: usize,
}

// The buffer owns its allocation and holds plain f32s; sharing &self or
// moving across threads is as safe as for Vec<f32>.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    /// An empty buffer. Does not allocate; the pointer is a 64-byte-aligned
    /// dangling sentinel so `as_ptr()` alignment holds even at len 0.
    pub const fn new() -> AlignedVec {
        AlignedVec {
            // BUF_ALIGN is non-zero, so this invalid-but-well-aligned
            // address is a valid NonNull dangling pointer.
            ptr: unsafe { NonNull::new_unchecked(BUF_ALIGN as *mut f32) },
            len: 0,
            cap: 0,
        }
    }

    /// A zero-filled buffer of `n` elements.
    pub fn zeroed(n: usize) -> AlignedVec {
        let mut v = AlignedVec::new();
        v.resize(n);
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<f32>(), BUF_ALIGN)
            .expect("AlignedVec layout overflow")
    }

    /// Resize to exactly `n` elements. Growth beyond capacity reallocates
    /// (preserving the prefix, zero-filling the rest); shrinking just drops
    /// `len` — capacity is retained, matching the arenas' grow-only use.
    pub fn resize(&mut self, n: usize) {
        if n > self.cap {
            let layout = Self::layout(n);
            // alloc_zeroed gives the zero fill for the grown region free
            let raw = unsafe { alloc_zeroed(layout) } as *mut f32;
            let Some(ptr) = NonNull::new(raw) else {
                handle_alloc_error(layout)
            };
            if self.len > 0 {
                unsafe {
                    std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len)
                };
            }
            if self.cap > 0 {
                unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
            }
            self.ptr = ptr;
            self.cap = n;
        } else if n > self.len {
            // reused capacity may hold stale values from a larger run
            unsafe { std::ptr::write_bytes(self.ptr.as_ptr().add(self.len), 0, n - self.len) };
        }
        self.len = n;
    }

    /// Set every live element to zero.
    pub fn clear_to_zero(&mut self) {
        unsafe { std::ptr::write_bytes(self.ptr.as_ptr(), 0, self.len) };
    }

    pub fn as_ptr(&self) -> *const f32 {
        self.ptr.as_ptr()
    }

    pub fn as_mut_ptr(&mut self) -> *mut f32 {
        self.ptr.as_ptr()
    }

    /// True when the storage satisfies [`BUF_ALIGN`] (always, by
    /// construction — exposed for the engine's debug assertions).
    pub fn is_aligned(&self) -> bool {
        self.ptr.as_ptr() as usize % BUF_ALIGN == 0
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.cap > 0 {
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }
}

impl Default for AlignedVec {
    fn default() -> AlignedVec {
        AlignedVec::new()
    }
}

impl Deref for AlignedVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> AlignedVec {
        let mut v = AlignedVec::zeroed(self.len);
        v.copy_from_slice(self);
        v
    }
}

impl std::fmt::Debug for AlignedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedVec")
            .field("len", &self.len)
            .field("cap", &self.cap)
            .finish()
    }
}

/// Minimum run length for the non-temporal path: below this the scalar
/// head/tail fixup dominates and a plain copy wins.
const STREAM_MIN: usize = 16;

/// Copy `src` into `dst` with non-temporal (streaming) stores where the
/// ISA allows.
///
/// NT stores bypass the cache hierarchy and combine into full-line DRAM
/// writes, eliminating the read-for-ownership a normal store performs on
/// a missing line — exactly the Table-1 write-allocate traffic the staged
/// engine pays when filling the `U`/`Z` arenas it will not read again
/// until a whole stage later.  They are only weakly *ordered*, not
/// incoherent: making them visible to other threads needs [`stream_fence`]
/// before the publishing synchronisation point, but partial cache lines
/// mixed with neighbouring workers' normal stores stay correct.
pub fn stream_copy(dst: &mut [f32], src: &[f32], isa: Isa) {
    assert_eq!(dst.len(), src.len());
    // SAFETY: equal-length slices; &mut guarantees no overlap.
    unsafe { stream_run(dst.as_mut_ptr(), src.as_ptr(), src.len(), isa) };
}

/// Raw-pointer form of [`stream_copy`] for shared-arena writers that hand
/// out disjoint regions by index (`SharedSlice` in the engine).
///
/// # Safety
///
/// `dst..dst + len` must be valid for writes, `src..src + len` valid for
/// reads, and the two ranges must not overlap.
pub unsafe fn stream_run(dst: *mut f32, src: *const f32, len: usize, isa: Isa) {
    #[cfg(target_arch = "x86_64")]
    if isa.clamp_to_host() >= Isa::Avx2 && len >= STREAM_MIN {
        // SAFETY: clamp_to_host guarantees AVX2 (hence AVX) is present;
        // caller upholds the range contract.
        unsafe { x86_stream_run(dst, src, len) };
        return;
    }
    let _ = isa;
    // SAFETY: caller upholds the range contract.
    unsafe { std::ptr::copy_nonoverlapping(src, dst, len) };
}

/// Make this thread's prior non-temporal stores globally visible.
///
/// NT stores are weakly ordered: on x86 even a `Release` atomic store
/// does not order them, so every worker must fence once before the
/// stage's join barrier.  No-op on targets without streaming stores.
#[inline]
pub fn stream_fence() {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: sfence is unconditionally available on x86_64.
    unsafe { std::arch::x86_64::_mm_sfence() };
}

/// The AVX interior: scalar head until `dst` reaches 32-byte alignment
/// (f32 pointers are always 4-aligned, so alignment is reachable), then
/// 8-wide `movntps`, then a scalar tail.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn x86_stream_run(dst: *mut f32, src: *const f32, len: usize) {
    use std::arch::x86_64::*;
    debug_assert_eq!(dst as usize % 4, 0);
    let head = (((32 - (dst as usize & 31)) & 31) / 4).min(len);
    for i in 0..head {
        *dst.add(i) = *src.add(i);
    }
    let mut i = head;
    while i + 8 <= len {
        _mm256_stream_ps(dst.add(i), _mm256_loadu_ps(src.add(i)));
        i += 8;
    }
    while i < len {
        *dst.add(i) = *src.add(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_aligned_and_unallocated() {
        let v = AlignedVec::new();
        assert!(v.is_empty());
        assert!(v.is_aligned());
        assert_eq!(v.len(), 0);
        assert_eq!(&v[..], &[] as &[f32]);
    }

    #[test]
    fn grow_zero_fills_and_preserves_prefix() {
        let mut v = AlignedVec::zeroed(7);
        assert!(v.iter().all(|&x| x == 0.0));
        for (i, x) in v.iter_mut().enumerate() {
            *x = i as f32 + 1.0;
        }
        v.resize(100);
        assert!(v.is_aligned());
        for i in 0..7 {
            assert_eq!(v[i], i as f32 + 1.0);
        }
        assert!(v[7..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shrink_then_regrow_rezeroes_reused_tail() {
        let mut v = AlignedVec::zeroed(32);
        for x in v.iter_mut() {
            *x = 5.0;
        }
        v.resize(4);
        assert_eq!(v.len(), 4);
        v.resize(32); // within retained capacity
        assert!(v[4..].iter().all(|&x| x == 0.0), "stale tail survived");
        assert!(v[..4].iter().all(|&x| x == 5.0));
    }

    #[test]
    fn alignment_holds_across_many_sizes() {
        for n in [1usize, 3, 15, 16, 17, 63, 64, 65, 1000] {
            let v = AlignedVec::zeroed(n);
            assert!(v.is_aligned(), "n={n}");
            assert_eq!(v.as_ptr() as usize % BUF_ALIGN, 0);
        }
    }

    #[test]
    fn clone_copies_contents() {
        let mut v = AlignedVec::zeroed(10);
        for (i, x) in v.iter_mut().enumerate() {
            *x = i as f32;
        }
        let w = v.clone();
        assert_eq!(&v[..], &w[..]);
        assert!(w.is_aligned());
    }

    #[test]
    fn clear_to_zero_wipes_live_elements() {
        let mut v = AlignedVec::zeroed(9);
        for x in v.iter_mut() {
            *x = 2.5;
        }
        v.clear_to_zero();
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn stream_copy_is_bitwise_exact_at_every_offset_and_length() {
        // misaligned destinations exercise the scalar head fixup; the
        // length sweep covers below/at/above STREAM_MIN and odd tails
        let src: Vec<f32> = (0..200).map(|i| i as f32 * 0.5 - 31.0).collect();
        for isa in Isa::available() {
            for off in 0..9usize {
                for len in [0usize, 1, 7, 15, 16, 17, 40, 64, 191] {
                    let mut dst = vec![f32::NAN; off + len];
                    stream_copy(&mut dst[off..], &src[..len], isa);
                    assert_eq!(&dst[off..], &src[..len], "isa={} off={off}", isa.name());
                    assert!(dst[..off].iter().all(|x| x.is_nan()), "front canary");
                }
            }
        }
        stream_fence();
    }
}
