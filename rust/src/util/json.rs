//! Minimal JSON: enough to read the artifact manifest and write bench
//! reports.  (serde is not available offline — DESIGN.md §3.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (the manifest only holds small
/// integers, exactly representable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < a.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let text = r#"{"artifacts": [{"name": "a", "m": 4, "inputs": [[1,2,3,4]], "ok": true}]}"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(arts[0].get("m").unwrap().as_usize(), Some(4));
        let inp = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap();
        assert_eq!(inp.len(), 4);
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a": [1, 2.5, "x\n", null, false], "b": {}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café \t ok""#).unwrap();
        assert_eq!(j.as_str(), Some("café \t ok"));
    }

    #[test]
    fn nested_depth() {
        let j = Json::parse("[[[[[1]]]]]").unwrap();
        let mut cur = &j;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }
}
