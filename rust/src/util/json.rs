//! Minimal JSON: enough to read the artifact manifest, write bench
//! reports, and round-trip tuning profiles.  (serde is not available
//! offline — DESIGN.md §3.)
//!
//! Profiles made this module the first consumer of [`Json::parse`] on
//! untrusted files, so errors are typed ([`JsonError`]) and carry the
//! byte position of the failure, and the emitter escapes everything the
//! parser can produce (quotes, backslashes, control characters) so
//! parse → emit → parse is the identity.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parse failure: what went wrong and the byte offset where.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where the failure was detected (the
    /// input length for truncation errors).
    pub pos: usize,
    /// Human-readable description of the failure.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.pos)
    }
}

impl std::error::Error for JsonError {}

fn err<T>(pos: usize, msg: impl Into<String>) -> Result<T, JsonError> {
    Err(JsonError {
        pos,
        msg: msg.into(),
    })
}

/// A JSON value. Numbers are kept as f64 (the manifest only holds small
/// integers, exactly representable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return err(p.i, "trailing data");
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < a.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            err(
                self.i,
                format!(
                    "expected '{}', found {:?}",
                    c as char,
                    self.peek().map(|b| b as char)
                ),
            )
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => err(self.i, format!("unexpected {:?}", c as char)),
            None => err(self.b.len(), "unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            err(self.i, "bad literal")
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        match std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
        {
            Some(n) => Ok(Json::Num(n)),
            None => err(start, "bad number"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return err(self.b.len(), "unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let Some(hex) = self.b.get(self.i + 1..self.i + 5) else {
                                return err(self.i, "truncated \\u escape");
                            };
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok());
                            let Some(code) = code else {
                                return err(self.i, "bad \\u escape");
                            };
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => {
                            return err(
                                self.i,
                                format!("bad escape {:?}", other.map(|b| b as char)),
                            )
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let Ok(rest) = std::str::from_utf8(&self.b[self.i..]) else {
                        return err(self.i, "invalid utf-8 in string");
                    };
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                Some(c) => return err(self.i, format!("expected , or ], found {:?}", c as char)),
                None => return err(self.b.len(), "unterminated array"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                Some(c) => return err(self.i, format!("expected , or }}, found {:?}", c as char)),
                None => return err(self.b.len(), "unterminated object"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let text = r#"{"artifacts": [{"name": "a", "m": 4, "inputs": [[1,2,3,4]], "ok": true}]}"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(arts[0].get("m").unwrap().as_usize(), Some(4));
        let inp = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap();
        assert_eq!(inp.len(), 4);
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a": [1, 2.5, "x\n", null, false], "b": {}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café \t ok""#).unwrap();
        assert_eq!(j.as_str(), Some("café \t ok"));
    }

    #[test]
    fn nested_depth() {
        let j = Json::parse("[[[[[1]]]]]").unwrap();
        let mut cur = &j;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }

    #[test]
    fn errors_carry_byte_positions() {
        // truncation points at the end of the input
        let e = Json::parse("{\"a\": [1, 2").unwrap_err();
        assert_eq!(e.pos, 11, "{e}");
        let e = Json::parse("\"unterminated").unwrap_err();
        assert_eq!(e.pos, 13, "{e}");
        // a syntax error points at the offending byte
        let e = Json::parse("[1, ?]").unwrap_err();
        assert_eq!(e.pos, 4, "{e}");
        let e = Json::parse("").unwrap_err();
        assert_eq!(e.pos, 0, "{e}");
        // Display embeds the position for log lines
        assert!(e.to_string().contains("at byte 0"), "{e}");
    }

    #[test]
    fn emitter_escapes_quotes_backslashes_and_control_chars() {
        let nasty = "q\" b\\ n\n t\t r\r bell\u{7} nul\u{0} café ∂".to_string();
        let v = Json::Obj(
            [(nasty.clone(), Json::Str(nasty.clone()))]
                .into_iter()
                .collect(),
        );
        let text = v.to_string_pretty();
        // control chars must leave as escapes, never raw bytes
        assert!(!text.contains('\u{7}'));
        assert!(text.contains("\\u0007"));
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get(&nasty).and_then(Json::as_str), Some(nasty.as_str()));
    }

    /// Depth-limited random JSON value with adversarial strings (quotes,
    /// backslashes, control characters, multi-byte UTF-8).
    fn gen_value(rng: &mut crate::util::Rng, depth: usize) -> Json {
        let palette = ['a', '"', '\\', '\n', '\t', '\u{3}', 'é', '∂', '/', ' '];
        let top = if depth < 3 { 6 } else { 4 };
        match rng.below(top) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => {
                // mix of exact integers and shortest-roundtrip floats
                if rng.below(2) == 0 {
                    Json::Num(rng.below(2_000_000) as f64 - 1e6)
                } else {
                    Json::Num((rng.next_f64() - 0.5) * 1e9)
                }
            }
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| palette[rng.below(palette.len())]).collect())
            }
            4 => {
                let n = rng.below(5);
                Json::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.below(5);
                Json::Obj(
                    (0..n)
                        .map(|i| {
                            let key: String =
                                (0..rng.range(1, 8)).map(|_| palette[rng.below(palette.len())]).collect();
                            (format!("{key}{i}"), gen_value(rng, depth + 1))
                        })
                        .collect(),
                )
            }
        }
    }

    #[test]
    fn parse_emit_parse_roundtrip_property() {
        crate::util::quickcheck::check("json roundtrip", 200, |rng| {
            let v = gen_value(rng, 0);
            let text = v.to_string_pretty();
            let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
            if back != v {
                return Err(format!("roundtrip mismatch: {v:?} vs {back:?}"));
            }
            Ok(())
        });
    }
}
