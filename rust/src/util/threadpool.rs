//! Static fork-join thread pool — the paper's parallelization substrate.
//!
//! The paper (§3, "Parallelization Through Static Scheduling", after
//! Zlateski & Seung 2017) assigns each core a statically computed, equal
//! share of work and executes each stage as a single fork-join.  This pool
//! reproduces that execution model on std threads: workers are spawned
//! once, and `run_static` hands worker `i` its precomputed shard `i`.
//! There is no work stealing by design — the *scheduler* (coordinator
//! layer) is responsible for equalizing the shards, as in the paper.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send>;

enum Msg {
    Run(Job),
    Stop,
}

/// Completion barrier for one fork-join wave: the caller blocks in `wait`
/// until every dispatched shard has called `finish`, and the first panic
/// payload (if any) is carried back to be re-raised on the caller.
struct Completion {
    state: Mutex<(usize, Option<PanicPayload>)>,
    cv: Condvar,
}

impl Completion {
    fn new(n: usize) -> Completion {
        Completion {
            state: Mutex::new((n, None)),
            cv: Condvar::new(),
        }
    }

    fn finish(&self, panic: Option<PanicPayload>) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        if g.1.is_none() {
            g.1 = panic;
        }
        if g.0 == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<PanicPayload> {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.cv.wait(g).unwrap();
        }
        g.1.take()
    }
}

/// Per-worker spawn callback: runs **on the worker thread** before it
/// serves its first job, receiving the worker index.  The core-pinning /
/// NUMA hook — a sharded service installs one that binds replica `r`'s
/// worker `i` to a core of `r`'s socket.
pub type SpawnHook = Arc<dyn Fn(usize) + Send + Sync>;

/// Construction options for [`ThreadPool::with_options`]: worker naming
/// and the per-worker spawn hook.
#[derive(Clone, Default)]
pub struct PoolOptions {
    /// worker threads are named `{prefix}-w{i}`; empty → `fftconv`
    pub name_prefix: String,
    /// runs once on each worker thread before its first job
    pub spawn_hook: Option<SpawnHook>,
}

impl PoolOptions {
    pub fn new() -> PoolOptions {
        PoolOptions::default()
    }

    /// Worker-name prefix (threads become `{prefix}-w{i}`).
    pub fn name_prefix(mut self, prefix: impl Into<String>) -> PoolOptions {
        self.name_prefix = prefix.into();
        self
    }

    /// Install the per-worker spawn callback (see [`SpawnHook`]).
    pub fn spawn_hook(mut self, hook: impl Fn(usize) + Send + Sync + 'static) -> PoolOptions {
        self.spawn_hook = Some(Arc::new(hook));
        self
    }
}

/// Spawn a named, long-lived *driver* thread — one that owns a service
/// or reactor loop rather than serving pool waves.  The optional hook
/// runs on the new thread before `body`, with `index` as its argument:
/// the same pinning/affinity seam as [`PoolOptions::spawn_hook`], so an
/// async front-end's reactor can be bound next to (or away from) its
/// workers with the same mechanism.
pub fn spawn_driver<T, F>(
    name: impl Into<String>,
    hook: Option<SpawnHook>,
    index: usize,
    body: F,
) -> thread::JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    thread::Builder::new()
        .name(name.into())
        .spawn(move || {
            if let Some(hook) = &hook {
                hook(index);
            }
            body()
        })
        .expect("spawn driver thread")
}

/// A fixed-size fork-join pool.
pub struct ThreadPool {
    senders: Vec<mpsc::Sender<Msg>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1) with default naming and no spawn hook.
    pub fn new(n: usize) -> Self {
        Self::with_options(n, PoolOptions::default())
    }

    /// Spawn `n` workers (n >= 1).  Each thread is named
    /// `{prefix}-w{i}`, and `opts.spawn_hook` runs on it — exactly once,
    /// before its first job — with the worker index.  The constructor
    /// waits for every hook to complete, so by the time it returns all
    /// pinning/affinity side effects are in place; a panicking hook is
    /// re-raised on the caller (after all workers checked in), not
    /// swallowed on a detached thread.
    pub fn with_options(n: usize, opts: PoolOptions) -> Self {
        let n = n.max(1);
        let prefix = if opts.name_prefix.is_empty() {
            "fftconv".to_string()
        } else {
            opts.name_prefix
        };
        // barrier only when there are side effects to wait for
        let ready = opts
            .spawn_hook
            .is_some()
            .then(|| Arc::new(Completion::new(n)));
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Msg>();
            senders.push(tx);
            let hook = opts.spawn_hook.clone();
            let ready = ready.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("{prefix}-w{i}"))
                    .spawn(move || {
                        if let Some(hook) = hook {
                            // a panicking hook must still check in, or
                            // the constructor would deadlock in wait()
                            let r = catch_unwind(AssertUnwindSafe(|| hook(i)));
                            ready.expect("barrier exists with hook").finish(r.err());
                        }
                        while let Ok(Msg::Run(job)) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        if let Some(ready) = ready {
            if let Some(p) = ready.wait() {
                resume_unwind(p);
            }
        }
        ThreadPool { senders, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Fork-join: run `shard(i)` on worker `i` for every worker, then wait.
    ///
    /// `shard` must be `Sync` because all workers borrow it concurrently.
    pub fn run_static<F>(&self, shard: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        let parts: Vec<usize> = (0..self.workers()).collect();
        self.run_parts(parts, |_, i| shard(i));
    }

    /// Fork-join over *owned* per-shard work items: `f(i, item)` runs
    /// concurrently for every item, then all join.
    ///
    /// Shards are dispatched to the **persistent workers** through their
    /// job channels and the caller blocks on a completion barrier — one
    /// wave costs two channel sends per shard instead of a thread spawn
    /// (the old implementation forked scoped threads per stage, ~3 spawn
    /// waves per batch on the staged engine).  The caller itself executes
    /// shard 0, so `parts.len()` shards run on `parts.len()` threads.
    ///
    /// Zero-copy sharding is unchanged: callers pre-split output buffers
    /// into disjoint `&mut` slices, move each into its work item, and need
    /// no synchronization — disjointness is proven to the borrow checker
    /// before the fork.  Panics in any shard are re-raised on the caller
    /// after the join (workers survive: shards run under `catch_unwind`).
    pub fn run_parts<T, F>(&self, parts: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(usize, T) + Send + Sync,
    {
        let n = parts.len();
        if n == 0 {
            return;
        }
        let mut iter = parts.into_iter();
        let first = iter.next().expect("n >= 1");
        if n == 1 {
            f(0, first);
            return;
        }
        let done = Completion::new(n - 1);
        let mut panic: Option<PanicPayload>;
        {
            let (f, done_ref) = (&f, &done);
            for (off, part) in iter.enumerate() {
                let i = off + 1;
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| f(i, part)));
                    done_ref.finish(r.err());
                });
                // SAFETY: lifetime erasure to cross the worker channel.
                // `done.wait()` below does not return until every job has
                // run `finish`, so the borrows of `f`, `done` and the
                // shard data strictly outlive the jobs.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                };
                if let Err(e) = self.senders[off % self.senders.len()].send(Msg::Run(job)) {
                    // worker unavailable (cannot happen while the pool is
                    // alive): run the shard inline so the barrier closes
                    if let Msg::Run(j) = e.0 {
                        j();
                    }
                }
            }
            // the caller is a full participant, not an idle joiner
            panic = catch_unwind(AssertUnwindSafe(|| f(0, first))).err();
            if let Some(p) = done.wait() {
                panic = panic.or(Some(p));
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    /// Submit one fire-and-forget job to the least-loaded worker
    /// (round-robin); used by the coordinator's async paths.
    pub fn submit(&self, job: Job) {
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let i = NEXT.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        let _ = self.senders[i].send(Msg::Run(job));
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Split `n` work items into `shards` contiguous ranges whose sizes differ
/// by at most one — the paper's "each core is assigned roughly the same
/// amount of computation" for uniform-cost items.
pub fn even_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    let shards = shards.max(1);
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for i in 0..shards {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split weighted items into `shards` contiguous ranges with approximately
/// equal total weight (greedy prefix partition).  Used when tile rows have
/// unequal cost (e.g. remainder tiles).
pub fn weighted_ranges(weights: &[f64], shards: usize) -> Vec<std::ops::Range<usize>> {
    let shards = shards.max(1);
    let total: f64 = weights.iter().sum();
    let target = total / shards as f64;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    let mut acc = 0.0;
    for i in 0..weights.len() {
        acc += weights[i];
        let remaining_shards = shards - out.len();
        let remaining_items = weights.len() - (i + 1);
        // close the shard when we reach the target, but never leave more
        // shards than items
        if (acc >= target && remaining_shards > 1) || remaining_items + 1 == remaining_shards {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0.0;
            if out.len() == shards - 1 {
                break;
            }
        }
    }
    out.push(start..weights.len());
    while out.len() < shards {
        out.push(weights.len()..weights.len());
    }
    out
}

/// Process-wide default pool sized to available parallelism.
pub fn default_pool() -> Arc<ThreadPool> {
    static POOL: Mutex<Option<Arc<ThreadPool>>> = Mutex::new(None);
    let mut g = POOL.lock().unwrap();
    g.get_or_insert_with(|| {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Arc::new(ThreadPool::new(n))
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_static_visits_every_worker() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        pool.run_static(|i| {
            hits.fetch_add(1 << (8 * i), Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0x01010101);
    }

    #[test]
    fn run_static_joins_before_return() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        pool.run_static(|_| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            sum.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn spawn_driver_names_thread_runs_hook_and_returns_value() {
        let hooked = Arc::new(AtomicU64::new(0));
        let log = hooked.clone();
        let hook: SpawnHook = Arc::new(move |i| {
            log.fetch_add(100 + i as u64, Ordering::SeqCst);
        });
        let h = spawn_driver("fftconv-fe", Some(hook), 3, || {
            std::thread::current().name().map(String::from)
        });
        let name = h.join().unwrap();
        assert_eq!(name.as_deref(), Some("fftconv-fe"));
        assert_eq!(hooked.load(Ordering::SeqCst), 103, "hook ran with index");
        // no hook: still named, still returns the body's value
        let h = spawn_driver("fftconv-fe2", None, 0, || 7u32);
        assert_eq!(h.join().unwrap(), 7);
    }

    #[test]
    fn even_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 16, 100] {
            for s in [1usize, 2, 3, 8] {
                let rs = even_ranges(n, s);
                assert_eq!(rs.len(), s);
                let covered: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(covered, n);
                let max = rs.iter().map(|r| r.len()).max().unwrap();
                let min = rs.iter().map(|r| r.len()).min().unwrap();
                assert!(max - min <= 1, "uneven: {rs:?}");
                // contiguity
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn weighted_ranges_balance() {
        let w = vec![1.0, 1.0, 1.0, 1.0, 4.0, 4.0];
        let rs = weighted_ranges(&w, 3);
        assert_eq!(rs.len(), 3);
        let sums: Vec<f64> = rs.iter().map(|r| w[r.clone()].iter().sum()).collect();
        let total: f64 = sums.iter().sum();
        assert!((total - 12.0).abs() < 1e-9);
        // no shard takes more than ~half the work
        assert!(sums.iter().all(|&s| s <= 8.0), "{sums:?}");
    }

    #[test]
    fn weighted_ranges_more_shards_than_items() {
        let rs = weighted_ranges(&[1.0, 1.0], 4);
        assert_eq!(rs.len(), 4);
        let covered: usize = rs.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 2);
    }

    #[test]
    fn run_parts_moves_disjoint_slices() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0.0f32; 9];
        {
            let mut rest: &mut [f32] = &mut data;
            let mut parts = Vec::new();
            for _ in 0..3 {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(3);
                parts.push(head);
                rest = tail;
            }
            pool.run_parts(parts, |i, slice| {
                for v in slice.iter_mut() {
                    *v = i as f32 + 1.0;
                }
            });
        }
        assert_eq!(data, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn shard_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_parts(vec![0usize, 1, 2], |i, _| {
                if i == 2 {
                    panic!("shard failed");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the caller");
        // the persistent workers caught the unwind and still serve waves
        let sum = AtomicU64::new(0);
        pool.run_parts(vec![1u64, 2, 3], |_, v| {
            sum.fetch_add(v, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn run_parts_with_more_parts_than_workers() {
        let pool = ThreadPool::new(2);
        let sum = AtomicU64::new(0);
        pool.run_parts((0..7u64).collect(), |_, v| {
            sum.fetch_add(v, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 21);
    }

    #[test]
    fn spawn_hook_runs_once_per_worker_before_first_job() {
        let hits = Arc::new(Mutex::new(Vec::<usize>::new()));
        let h = hits.clone();
        let pool = ThreadPool::with_options(
            4,
            PoolOptions::new()
                .name_prefix("hooked")
                .spawn_hook(move |i| h.lock().unwrap().push(i)),
        );
        // with_options waits on the hook barrier: all hooks already ran
        let mut seen = hits.lock().unwrap().clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "once per worker, exactly");
        // hooks never re-run on later waves
        pool.run_static(|_| {});
        pool.run_static(|_| {});
        assert_eq!(hits.lock().unwrap().len(), 4);
    }

    #[test]
    fn spawn_hook_sees_the_named_worker_thread() {
        let names = Arc::new(Mutex::new(Vec::<(usize, String)>::new()));
        let n = names.clone();
        let _pool = ThreadPool::with_options(
            2,
            PoolOptions::new().name_prefix("fftconv-r1").spawn_hook(move |i| {
                let name = thread::current().name().unwrap_or("").to_string();
                n.lock().unwrap().push((i, name));
            }),
        );
        let mut got = names.lock().unwrap().clone();
        got.sort();
        assert_eq!(
            got,
            vec![(0, "fftconv-r1-w0".to_string()), (1, "fftconv-r1-w1".to_string())]
        );
    }

    #[test]
    fn spawn_hook_panic_reaches_the_constructor() {
        let r = std::panic::catch_unwind(|| {
            ThreadPool::with_options(
                2,
                PoolOptions::new().spawn_hook(|i| {
                    if i == 1 {
                        panic!("pinning failed");
                    }
                }),
            )
        });
        assert!(r.is_err(), "hook panic must not be swallowed");
    }

    #[test]
    fn submit_runs_job() {
        let pool = ThreadPool::new(2);
        let (tx, rx) = mpsc::channel();
        pool.submit(Box::new(move || {
            tx.send(42u32).unwrap();
        }));
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(5)), Ok(42));
    }
}
