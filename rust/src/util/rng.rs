//! Deterministic xorshift64* RNG: test vectors, property tests, synthetic
//! workloads.  Not cryptographic; chosen for reproducibility without
//! external crates.

/// xorshift64* generator (Vigna 2016). Never yields a zero state.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [-1, 1) — the synthetic activation/weight distribution.
    #[inline]
    pub fn next_f32_signed(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// A vector of signed uniform f32s.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32_signed()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}
