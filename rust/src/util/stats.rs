//! Small statistics helpers, including the paper's model-fit metrics
//! (relative RMSE and "fitness", §5.2).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Median (of a copy); 0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (mean(&xs.iter().map(|x| (x - m) * (x - m)).collect::<Vec<_>>())).sqrt()
}

/// Relative root-mean-square error between predictions and measurements:
/// rRMSE = sqrt(mean(((pred - meas) / meas)^2)) — the paper reports 0.079
/// for Regular-FFT vs Winograd and 0.1 for Gauss-FFT vs Winograd.
pub fn rrmse(pred: &[f64], meas: &[f64]) -> f64 {
    assert_eq!(pred.len(), meas.len());
    assert!(!pred.is_empty());
    let se: f64 = pred
        .iter()
        .zip(meas)
        .map(|(p, m)| {
            let rel = (p - m) / m;
            rel * rel
        })
        .sum::<f64>()
        / pred.len() as f64;
    se.sqrt()
}

/// The paper's fitness metric (§5.2 footnote): 100 / (1 + rRMSE), in %.
pub fn fitness(pred: &[f64], meas: &[f64]) -> f64 {
    100.0 / (1.0 + rrmse(pred, meas))
}

/// Geometric mean; panics on non-positive input.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_known() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rrmse_zero_for_perfect_fit() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(rrmse(&xs, &xs), 0.0);
        assert_eq!(fitness(&xs, &xs), 100.0);
    }

    #[test]
    fn rrmse_matches_hand_computation() {
        // pred 10% high everywhere -> rRMSE = 0.1, fitness ~ 90.9%
        let meas = [1.0, 2.0, 4.0];
        let pred = [1.1, 2.2, 4.4];
        assert!((rrmse(&pred, &meas) - 0.1).abs() < 1e-12);
        assert!((fitness(&pred, &meas) - 100.0 / 1.1).abs() < 1e-9);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
