//! In-repo property-testing helper (proptest substitute, DESIGN.md §3).
//!
//! A property is a closure from a seeded [`crate::util::Rng`] to
//! `Result<(), String>`.  The runner executes it over many seeds and, on
//! failure, reports the failing seed so the case can be replayed as a
//! plain unit test.  Generators for the common shapes live here too.

use super::rng::Rng;

/// Run `prop` for `cases` seeds. Panics (with the seed) on first failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two slices are element-wise close (absolute + relative).
pub fn assert_close(a: &[f32], b: &[f32], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    let mut worst = 0.0f64;
    let mut worst_i = 0usize;
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let diff = (x as f64 - y as f64).abs();
        let bound = atol + rtol * (y as f64).abs();
        if diff > bound && diff > worst {
            worst = diff;
            worst_i = i;
        }
    }
    if worst > 0.0 {
        return Err(format!(
            "max violation {worst:.3e} at index {worst_i}: {} vs {}",
            a[worst_i], b[worst_i]
        ));
    }
    Ok(())
}

/// Random small convolution-problem dimensions for property tests.
#[derive(Clone, Copy, Debug)]
pub struct ConvDims {
    pub batch: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub r: usize,
    pub m: usize,
}

pub fn gen_conv_dims(rng: &mut Rng) -> ConvDims {
    let r = [1, 2, 3, 4, 5][rng.below(5)];
    let m = rng.range(1, 8);
    let min_hw = r; // valid conv needs h >= r
    ConvDims {
        batch: rng.range(1, 3),
        c_in: rng.range(1, 6),
        c_out: rng.range(1, 6),
        h: rng.range(min_hw.max(4), 18),
        w: rng.range(min_hw.max(4), 18),
        r,
        m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |rng| {
            let v = rng.next_f64();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range: {v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn check_reports_failures() {
        check("failing", 10, |rng| {
            if rng.next_f64() < 2.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn assert_close_rejects_far() {
        assert!(assert_close(&[1.0], &[2.0], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }

    #[test]
    fn conv_dims_valid() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let d = gen_conv_dims(&mut rng);
            assert!(d.h >= d.r && d.w >= d.r && d.m >= 1);
        }
    }
}
