//! Serving quickstart (docs/ARCHITECTURE.md §9): the async front-end in
//! one page — launch a `FrontEnd` over a `ConvService`, push traffic at
//! it from producer threads through cloned handles, watch admission
//! control shed an over-quota tenant with structured errors, and shut
//! down cleanly with every admitted request answered.
//!
//!     cargo run --release --example quickstart
//!
//! Exits non-zero if any step misbehaves — this doubles as a smoke test
//! for the reactor path.

use fftconv::conv::{direct, ConvAlgorithm, ConvProblem, Tensor4};
use fftconv::coordinator::{
    ConvRequest, ConvService, FrontEnd, FrontEndOptions, ServiceError, TenantId, TenantQuota,
    TuningPolicy,
};
use fftconv::model::machine::xeon_gold;
use std::thread;
use std::time::Duration;

const ALGO: ConvAlgorithm = ConvAlgorithm::RegularFft { m: 6 };

fn main() {
    let p = ConvProblem::unit(1, 8, 8, 20, 20, 3);
    let w = Tensor4::random(p.weight_shape(), 42);

    // 1. build the service exactly as before, then hand it to a
    // FrontEnd: a driver thread takes ownership, forms batches on the
    // deadline timer, and nobody ever calls tick()/flush() again
    let mut svc = ConvService::builder(xeon_gold())
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_millis(2))
        .tuning_policy(TuningPolicy::Analytic)
        .completion_ttl(Duration::from_secs(5)) // abandoned tickets expire
        .build();
    let layer = svc
        .register_with_algo("conv3x3", p, w.clone(), ALGO)
        .expect("register");
    let fe = FrontEnd::with_options(
        svc,
        FrontEndOptions::new()
            .intake_limit(256)
            // tenant 9 gets 4 requests and not one more (zero refill)
            .quota(TenantId(9), TenantQuota::with_burst(0.0, 4.0)),
    );

    // 2. producer threads submit through cloned handles; each submit
    // returns a TicketWaiter immediately and the thread parks on wait()
    // (condvar, no spin) until the reactor delivers its response
    let mut producers = Vec::new();
    for t in 0..3u32 {
        let handle = fe.handle();
        let w = w.clone();
        producers.push(thread::spawn(move || {
            for i in 0..8u64 {
                let x = Tensor4::random([1, 8, 20, 20], 1000 + u64::from(t) * 100 + i);
                let req = ConvRequest::with_tenant(layer, x.clone(), TenantId(t))
                    .expect("single image");
                let resp = handle
                    .submit(req)
                    .expect("under quota, under the intake bound")
                    .wait()
                    .expect("admitted work always resolves");
                let want = direct::reference(&p, &x, &w);
                assert!(
                    resp.output.max_abs_diff(&want) < 2e-3 * want.max_abs().max(1.0),
                    "async response must match the direct oracle"
                );
            }
        }));
    }
    for producer in producers {
        producer.join().expect("producer thread");
    }

    // 3. admission control in action: tenant 9's burst is 4, so its
    // fifth submit sheds with a structured error — no panic, no queue
    let x = Tensor4::random([1, 8, 20, 20], 7);
    let mut ok = 0;
    let mut shed = 0;
    for _ in 0..6 {
        let req = ConvRequest::with_tenant(layer, x.clone(), TenantId(9)).expect("single image");
        match fe.submit(req) {
            Ok(waiter) => {
                waiter.wait().expect("admitted");
                ok += 1;
            }
            Err(ServiceError::QuotaExceeded { tenant }) => {
                assert_eq!(tenant, TenantId(9));
                shed += 1;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }

    // 4. the shared metrics now carry both halves of the story: the
    // front-end's admission gauges and the executor's batch quantiles
    let snap = fe.snapshot();
    println!(
        "quickstart: {} admitted / {} quota-shed, {} batches (mean {:.1} img), \
         queue-wait p95 {:.3} ms, exec p95 {:.3} ms",
        snap.admitted, snap.quota_rejected, snap.batches, snap.mean_batch, snap.queue_p95_ms,
        snap.p95_ms
    );

    // 5. shutdown drains everything and returns the service
    let svc = fe.shutdown();
    if ok != 4 || shed != 2 || snap.quota_rejected != 2 || svc.pending() != 0 {
        eprintln!("error: quickstart invariants violated (ok {ok}, shed {shed})");
        std::process::exit(1);
    }
}
