//! Profile warm-start quickstart (docs/ARCHITECTURE.md §8): export a
//! tuning snapshot from a short serving run, save it to JSON, load it
//! into a fresh service, and verify the warm-started run serves its
//! first batches off the imported verdicts with zero re-measurements.
//!
//!     cargo run --release --example profile_warmstart [profile.json]
//!
//! Exits non-zero if any step fails — verify.sh runs it as the
//! export → import → serve smoke test.

use fftconv::conv::{ConvAlgorithm, ConvProblem, Tensor4};
use fftconv::coordinator::{ConvRequest, ConvService, LayerId, TuningPolicy, TuningProfile};
use fftconv::model::machine::xeon_gold;
use std::time::Duration;

const ALGO: ConvAlgorithm = ConvAlgorithm::RegularFft { m: 6 };

fn serve(svc: &mut ConvService, id: LayerId, n: usize, seed: u64) {
    for i in 0..n {
        let x = Tensor4::random([1, 8, 20, 20], seed + i as u64);
        let t = svc
            .submit(ConvRequest::new(id, x).expect("single image"))
            .expect("known layer");
        svc.take(t).expect("batch of 1 executes on submit");
    }
}

fn main() {
    let path = std::env::args()
        .nth(1)
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("fftconv-profile-{}.json", std::process::id()))
        });
    let p = ConvProblem::unit(1, 8, 8, 20, 20, 3);
    let w = Tensor4::random(p.weight_shape(), 7);

    // 1. a measuring service earns verdicts from live traffic
    let mut src = ConvService::builder(xeon_gold())
        .workers(2)
        .max_batch(1)
        .max_wait(Duration::from_millis(1))
        .tuning_policy(TuningPolicy::Measured)
        .build();
    let id = src
        .register_with_algo("conv3x3", p, w.clone(), ALGO)
        .expect("register");
    serve(&mut src, id, 4, 100);
    let profile = src.export_profile();
    let settled = profile.entries.iter().filter(|e| e.settled).count();
    if settled == 0 {
        eprintln!("error: the serving run settled no verdict to export");
        std::process::exit(1);
    }

    // 2. save → load round-trip through the JSON snapshot
    profile.save(&path).expect("save profile");
    let loaded = TuningProfile::load(&path).expect("load profile");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, profile, "save/load must round-trip bit-exact");

    // 3. a fresh service on the same machine warm-starts from the file:
    // first batches serve the imported verdicts, nothing is re-measured
    let mut svc = ConvService::builder(xeon_gold())
        .workers(2)
        .max_batch(1)
        .max_wait(Duration::from_millis(1))
        .tuning_policy(TuningPolicy::Measured)
        .profile(loaded)
        .build();
    let id = svc
        .register_with_algo("conv3x3", p, w, ALGO)
        .expect("register");
    serve(&mut svc, id, 4, 200);

    let hits = svc.verdict_warm_hits();
    let remeasured = svc.decay_stats().remeasurements;
    println!(
        "profile warm-start: {settled} settled verdicts exported, \
         {hits} warm hits, {remeasured} re-measurements"
    );
    if hits == 0 || remeasured != 0 {
        eprintln!("error: warm start did not serve the imported verdicts measurement-free");
        std::process::exit(1);
    }
}
