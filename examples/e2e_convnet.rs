//! End-to-end driver (DESIGN.md §6): proves all three layers compose and
//! runs the paper's workload on a real small model.
//!
//! Phase 1 — AOT path: load the Python-lowered HLO artifacts (Pallas
//! kernels inside JAX graphs), execute the 3-layer ConvNet per method on
//! the PJRT CPU client from rust, and cross-validate the numerics
//! against the native engine.  Python is not running.
//!
//! Phase 2 — native serving path: register the 12 distinct VGG/AlexNet
//! layers (host-scaled) with model-chosen algorithms, push batched
//! requests through the coordinator, and report per-layer latency +
//! the paper's AlexNet headline comparison.
//!
//! `make artifacts && cargo run --release --example e2e_convnet`

use fftconv::conv::{self, ConvAlgorithm, Tensor4};
use fftconv::coordinator::{ConvRequest, ConvService, LayerId, Ticket};
use fftconv::harness::figures::alexnet_totals;
use fftconv::harness::BenchConfig;
use fftconv::model::machine::probe_host;
use fftconv::model::paper_data;
use fftconv::nets;
use fftconv::runtime::{artifacts_available, default_artifact_dir, Runtime};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // ---------------- Phase 1: AOT artifacts through PJRT ----------------
    let dir = default_artifact_dir();
    if artifacts_available(&dir) {
        println!("== Phase 1: AOT artifacts (jax+pallas -> HLO text -> rust PJRT)");
        let rt = Runtime::open(&dir)?;
        let nets_arts: Vec<_> = rt
            .artifacts()
            .iter()
            .filter(|a| a.kind == "convnet")
            .cloned()
            .collect();
        let base = &nets_arts[0];
        let inputs: Vec<Tensor4> = base
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor4::random([s[0], s[1], s[2], s[3]], 42 + i as u64))
            .collect();
        let refs: Vec<&Tensor4> = inputs.iter().collect();
        let mut outputs = Vec::new();
        for art in &nets_arts {
            let t0 = std::time::Instant::now();
            let out = rt.execute(&art.name, &refs)?;
            println!(
                "  {:24} -> {:?} in {:6.1} ms (compile cached after first)",
                art.name,
                out.shape,
                t0.elapsed().as_secs_f64() * 1e3
            );
            outputs.push((art.name.clone(), out));
        }
        let (base_name, base_out) = &outputs[0];
        for (name, out) in &outputs[1..] {
            let diff = out.max_abs_diff(base_out) / base_out.max_abs().max(1.0);
            println!("  {name} vs {base_name}: rel diff {diff:.2e}");
            assert!(diff < 1e-2, "convnet methods disagree");
        }
        println!("  all AOT convnet methods agree ✓\n");
    } else {
        println!("== Phase 1 SKIPPED: run `make artifacts` first\n");
    }

    // ---------------- Phase 2: native serving path ----------------
    println!("== Phase 2: coordinator serving host-scaled VGG+AlexNet layers");
    let host = probe_host();
    println!(
        "  host: {} (CMR {:.1})",
        host.name,
        host.cmr()
    );
    let cfg = BenchConfig::from_env();
    let layers = nets::host_layers(1, cfg.max_x.min(34)); // request-sized images
    let mut svc = ConvService::builder(host)
        .workers(2)
        .max_batch(4)
        .max_wait(Duration::from_millis(5))
        .build();
    let handles: Vec<LayerId> = layers
        .iter()
        .map(|layer| {
            let mut p = layer.problem();
            p.batch = 4;
            let w = Tensor4::random(p.weight_shape(), 7);
            let id = svc.register(layer.name, p, w)?;
            println!(
                "  registered {:10} -> {}",
                layer.name,
                svc.layer(id).unwrap().algo.name()
            );
            Ok(id)
        })
        .collect::<Result<_, fftconv::ServiceError>>()?;
    // push 4 requests per layer (fills one batch each), claiming each
    // ticket's own response
    let mut tickets: Vec<Ticket> = Vec::new();
    for (li, (layer, id)) in layers.iter().zip(&handles).enumerate() {
        let p = layer.problem();
        for j in 0..4u64 {
            let x = Tensor4::random([1, p.c_in, p.h, p.w], 100 + 4 * li as u64 + j);
            tickets.push(svc.submit(ConvRequest::new(*id, x)?)?);
        }
    }
    svc.flush();
    let done = tickets.iter().filter(|t| svc.take(**t).is_some()).count();
    let snap = svc.metrics.snapshot();
    println!(
        "\n  served {done}/{} requests in {} batches (mean batch {:.1})",
        tickets.len(),
        snap.batches,
        snap.mean_batch
    );
    println!(
        "  latency: p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms",
        snap.p50_ms, snap.p95_ms, snap.max_ms
    );
    assert_eq!(done, tickets.len(), "every ticket answered");

    // correctness spot check through the full service path
    let spot_id = handles[7]; // vgg5.1-scaled
    let p = layers[7].problem();
    let x = Tensor4::random([1, p.c_in, p.h, p.w], 999);
    let w = svc.layer(spot_id).unwrap().weights.clone();
    let t = svc.submit(ConvRequest::new(spot_id, x.clone())?)?;
    svc.flush();
    let resp = svc.take(t).expect("spot ticket answered");
    let want = conv::run(ConvAlgorithm::Direct, &x, &w);
    let diff = resp.output.max_abs_diff(&want) / want.max_abs();
    println!("  service output vs direct oracle: rel diff {diff:.2e} ✓");
    assert!(diff < 1e-3);

    // ---------------- Phase 3: the paper's headline ----------------
    println!("\n== Phase 3: AlexNet conv-total comparison (paper headline)");
    let (wino_ms, fft_ms) = alexnet_totals(&cfg);
    println!(
        "  host-scaled AlexNet conv total: winograd {wino_ms:.1} ms, \
         regular-fft {fft_ms:.1} ms ({:.2}x)",
        wino_ms / fft_ms
    );
    println!(
        "  paper (20-core Xeon Gold, full scale): {:.2} ms -> {:.2} ms ({:.2}x)",
        paper_data::ALEXNET_TOTAL_MS_WINOGRAD,
        paper_data::ALEXNET_TOTAL_MS_REGULAR_FFT,
        paper_data::ALEXNET_TOTAL_MS_WINOGRAD / paper_data::ALEXNET_TOTAL_MS_REGULAR_FFT
    );
    println!("\ne2e driver complete ✓");
    Ok(())
}
