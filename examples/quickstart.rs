//! Quickstart: convolve one layer with every algorithm and check they
//! agree.  `cargo run --release --example quickstart`
//!
//! Also demonstrates the execution-mode knobs: every tiled plan runs
//! either **staged** (three fork-join stages over global U/Z arenas) or
//! **fused** (one fork-join of cache-resident tile panels, L3 fusion).
//! `ExecPolicy::Auto` lets the engine fuse whenever a panel fits the
//! cache budget; the scheduler seeds that choice from the roofline model
//! (`model::select::choose_exec`) and — under `TuningPolicy::Measured`
//! or `Hybrid` — re-resolves it **per batch-size bucket** from real
//! timings (docs/ARCHITECTURE.md §4).

use fftconv::conv::{
    self, ConvAlgorithm, ConvProblem, ExecPolicy, LayerPlan, PlanOptions, Tensor4,
};
use fftconv::coordinator::{ConvRequest, ConvService, DecayPolicy, StaticScheduler, TuningPolicy};
use fftconv::nets::graph::{LayerSpec, NetworkGraph};
use std::time::Instant;

fn main() {
    // a small VGG-ish layer: 32 -> 32 channels, 34x34 input, 3x3 kernels
    // (unit stride, no padding; ConvProblem::with_geometry adds both)
    let problem = ConvProblem::unit(2, 32, 32, 34, 34, 3);
    let x = Tensor4::random(problem.input_shape(), 1);
    let w = Tensor4::random(problem.weight_shape(), 2);

    println!("problem: {problem:?}");
    println!("direct FLOPs: {:.2} GFLOP\n", problem.direct_flops() as f64 / 1e9);

    let reference = conv::run(ConvAlgorithm::Direct, &x, &w);
    for algo in [
        ConvAlgorithm::Direct,
        ConvAlgorithm::Im2col,
        ConvAlgorithm::Winograd { m: 4 },     // F(4^2,3^2): the vendor sweet spot
        ConvAlgorithm::RegularFft { m: 6 },   // 𝔉(6^2,3^2): t = 8
        ConvAlgorithm::RegularFft { m: 14 },  // 𝔉(14^2,3^2): t = 16
        ConvAlgorithm::GaussFft { m: 6 },
    ] {
        let t0 = Instant::now();
        let out = conv::run(algo, &x, &w);
        let dt = t0.elapsed();
        let err = out.max_abs_diff(&reference) / reference.max_abs();
        println!(
            "{:22} {:8.2} ms   rel.err {:.2e}",
            algo.name(),
            dt.as_secs_f64() * 1e3,
            err
        );
        assert!(err < 1e-3, "{} disagrees with direct", algo.name());
    }
    println!("\nall algorithms agree ✓");

    // --- execution-mode override knobs -----------------------------------
    // Staged vs fused is normally picked by the roofline selector; pin it
    // explicitly (and set the per-worker cache budget that sizes the fused
    // tile panel) via PlanOptions:
    println!("\nexec-mode override (RegularFft m=6):");
    for exec in [ExecPolicy::Staged, ExecPolicy::Fused, ExecPolicy::Auto] {
        let opts = PlanOptions {
            exec,
            fused_budget: 1 << 20, // bytes of per-worker cache for panels
            ..PlanOptions::default()
        };
        let mut plan = LayerPlan::with_options(
            ConvAlgorithm::RegularFft { m: 6 },
            &w,
            problem.h,
            problem.w,
            1,
            opts,
        );
        let t0 = Instant::now();
        let out = plan.run(&x, None);
        let err = out.max_abs_diff(&reference) / reference.max_abs();
        println!(
            "  {:?} -> resolved {:8} ({} tiles/panel) {:8.2} ms   rel.err {:.2e}",
            exec,
            plan.exec_mode().name(),
            plan.panel_tiles(),
            t0.elapsed().as_secs_f64() * 1e3,
            err
        );
        assert!(err < 1e-3);
    }

    // --- measured autotuning: per-batch staged/fused re-resolution -------
    // The scheduler does NOT trust the roofline once and forever: each
    // batch size bucket (1, 2, 4, ... — powers of two) of the same layer
    // plan gets its own staged-vs-fused verdict.
    //
    //   TuningPolicy::Analytic  -- trust the model seed, never measure.
    //   TuningPolicy::Measured  -- unsettled batches run BOTH pipelines
    //       back to back, keeping the faster once both samples are warm
    //       (cold runs that grow scratch never count).  Worth it for
    //       long-lived serving layers: a couple of double batches per
    //       bucket buy the empirically fastest path forever after.  Not
    //       worth it for short-lived layers or strict per-batch latency
    //       SLOs (the measuring batches do the layer twice).
    //   TuningPolicy::Hybrid    -- runs the model's pick until it has a
    //       warm sample, then the alternative, then the winner sticks:
    //       no batch is ever run twice, settling a few batches later.
    println!("\nper-batch exec re-resolution (TuningPolicy::Hybrid):");
    let mut sched = StaticScheduler::new(2);
    sched.set_tuning_policy(TuningPolicy::Hybrid);
    let algo = ConvAlgorithm::RegularFft { m: 6 };
    // the same plan serves batch 1 (latency traffic) and batch 8
    // (throughput traffic); each bucket tunes independently
    for b in [1usize, 1, 1, 1, 8, 8, 8, 8] {
        let xb = Tensor4::random([b, problem.c_in, problem.h, problem.w], 7 + b as u64);
        let t0 = Instant::now();
        let _ = sched.run_batch(algo, &xb, &w);
        let snap = sched.tuning_for(algo, &xb, &w).expect("tuned");
        println!(
            "  batch {b} (bucket {}): analytic {:7} resolved {:7} settled {:5}  {:6.2} ms",
            snap.bucket,
            snap.analytic.name(),
            snap.resolved.name(),
            snap.settled,
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }
    println!(
        "model overruled on {} bucket(s) by measurement",
        sched.tuning_disagreements()
    );

    // --- drift-aware decay: verdicts are leases, not marriages -----------
    // On a long-lived service the staged-vs-fused winner moves with
    // machine state (thermal throttling, co-tenants, cache pressure), so
    // settled verdicts can be set to expire:
    //
    //   DecayPolicy::Never            -- verdicts are final (default).
    //   DecayPolicy::AfterBatches(n)  -- re-confirm after serving n batches.
    //   DecayPolicy::OnDrift{rel_tol} -- warm samples of the winning mode
    //       feed an EWMA; one deviating >rel_tol re-opens the verdict and
    //       shadow-re-measures the losing mode (at most one re-measuring
    //       bucket per batch wave, so serving latency stays flat).
    //   DecayPolicy::OnDriftSigma{k} -- the variance-aware flavor: the
    //       EWMA also tracks the stream's spread and only a sample more
    //       than k standard deviations from the mean re-opens the
    //       verdict — use on noisy co-tenanted hosts where a fixed
    //       rel_tol would churn on every scheduling hiccup (k = 3 is
    //       the usual control-chart setting).
    sched.set_decay_policy(DecayPolicy::OnDrift { rel_tol: 0.5 });
    for b in [8usize, 8, 8] {
        let xb = Tensor4::random([b, problem.c_in, problem.h, problem.w], 40 + b as u64);
        let _ = sched.run_batch(algo, &xb, &w);
    }
    println!(
        "decay after 3 more batches: {:?} ({} bucket(s) re-confirming)",
        sched.decay_stats(),
        sched.stale_entries()
    );

    // --- the v2 serving surface: handles, tickets, builder, errors -------
    // ConvService is the layer above the scheduler: named registration
    // (once) returns a copyable LayerId; submits carry the handle and
    // return a Ticket; each caller claims exactly its own responses.
    println!("\nserving API v2 (LayerId + Ticket):");
    let mut svc = ConvService::builder(fftconv::model::machine::xeon_gold())
        .workers(2)
        .max_batch(2)
        .max_wait(std::time::Duration::from_millis(2))
        .tuning_policy(TuningPolicy::Hybrid)
        .build();
    let conv1 = svc
        .register("conv1", problem, w.clone())
        .expect("fresh name, matching weights");
    assert_eq!(svc.resolve("conv1"), Some(conv1)); // name -> handle, once
    let (xa, xb) = (
        Tensor4::random([1, problem.c_in, problem.h, problem.w], 50),
        Tensor4::random([1, problem.c_in, problem.h, problem.w], 51),
    );
    let ta = svc.submit(ConvRequest::new(conv1, xa).unwrap()).unwrap();
    let tb = svc.submit(ConvRequest::new(conv1, xb).unwrap()).unwrap();
    svc.flush();
    let (ra, rb) = (svc.take(ta).unwrap(), svc.take(tb).unwrap());
    println!(
        "  ticket {} -> batch of {}, {:.2} ms; ticket {} -> batch of {}",
        ta.id(),
        ra.batch_size,
        ra.latency * 1e3,
        tb.id(),
        rb.batch_size,
    );
    // weight updates are first-class: the plan re-warms, stale tuning
    // entries for the old weights are deleted, the next batch serves
    // the new weights
    let w2 = Tensor4::random(problem.weight_shape(), 52);
    svc.swap_weights(conv1, w2).expect("same weight shape");
    // errors are typed values, not panics or strings
    let err = ConvRequest::new(conv1, Tensor4::zeros([2, 1, 1, 1])).unwrap_err();
    println!("  structured error demo: {err}");

    // --- serving a whole network -----------------------------------------
    // One registration compiles a full network: per-layer algorithms are
    // resolved (pin or roofline), every plan is warmed once, and a run
    // flows layer N's output into layer N+1 through two grow-only
    // ping-pong arenas — no per-layer round trip, no steady-state
    // allocation (docs/ARCHITECTURE.md §1).  Strided and 1x1 layers are
    // first-class: the stem below runs Direct, the head runs the 1x1
    // GEMM fast path, the 3x3 bodies run a tiled transform.
    println!("\nwhole-network serving (register_network + submit_network):");
    let graph = NetworkGraph::new("demo", 3, 16, 16)
        .layer(LayerSpec::strided("stem", 8, 3, 2, 1)) // 16 -> 8, Direct
        .layer(LayerSpec::conv("body1", 16, 3, 1))     // 8 -> 8, tiled
        .layer(LayerSpec::conv("body2", 16, 3, 1))     // 8 -> 8, tiled
        .layer(LayerSpec::pointwise("head", 10));      // 1x1 GEMM path
    let net_weights: Vec<Tensor4> = graph
        .problems(1)
        .expect("valid chain")
        .iter()
        .enumerate()
        .map(|(i, p)| Tensor4::random(p.weight_shape(), 60 + i as u64))
        .collect();
    let net = svc
        .register_network("demo", graph, net_weights, 2)
        .expect("fresh name, matching weights");
    for layer in svc.network(net).unwrap().net.layers() {
        println!("  layer {:8} -> {}", layer.name, layer.algo.name());
    }
    let builds_before = svc.plan_builds();
    let img = Tensor4::random([1, 3, 16, 16], 70);
    let ticket = svc.submit_network(net, img).expect("matching input shape");
    svc.flush();
    let resp = svc.take(ticket).expect("executed");
    println!(
        "  output {:?}, plans warmed at registration: {} new builds serving",
        resp.output.shape,
        svc.plan_builds() - builds_before
    );

    // --- profile warm-start: verdicts survive the process ----------------
    // The tuning table's shareable half serializes (docs/ARCHITECTURE.md
    // §8): export_profile() snapshots verdicts + EWMA streams + the
    // calibrated machine ceilings; a fresh service built with
    // .profile(..) imports matching-machine entries as Settled and
    // serves its first batches with zero re-measurement (mismatched
    // ceilings import Stale, and the decay machinery re-confirms them
    // on local timings instead).  On disk: profile.save(path) /
    // TuningProfile::load(path) — see examples/profile_warmstart.rs for
    // the end-to-end smoke verify.sh runs.
    let profile = svc.export_profile();
    println!(
        "\nprofile warm-start: exported {} tuning entries ({} settled) for {}",
        profile.entries.len(),
        profile.entries.iter().filter(|e| e.settled).count(),
        profile.machine.name,
    );
    let mut warm = ConvService::builder(fftconv::model::machine::xeon_gold())
        .workers(2)
        .tuning_policy(TuningPolicy::Hybrid)
        .profile(profile)
        .build();
    warm.register("conv1", problem, w.clone())
        .expect("fresh service, fresh name");
    println!(
        "  fresh service imported {} entries, re-measurements owed: {}",
        warm.tuning_entries(),
        warm.decay_stats().remeasurements,
    );
}
