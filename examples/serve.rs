//! Serving demo: a steady stream of mixed-layer convolution requests
//! through the batching coordinator, with latency metrics — the
//! "coordinator as a service" view of the L3 layer.
//!
//! `cargo run --release --example serve`

use fftconv::conv::{ConvProblem, Tensor4};
use fftconv::coordinator::{ConvRequest, ConvService};
use fftconv::model::machine::probe_host;
use fftconv::util::Rng;
use std::time::Duration;

fn main() {
    let host = probe_host();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut svc = ConvService::new(host, workers, 8, Duration::from_millis(2));

    // three registered layers of different shapes
    let specs = [
        ("small", ConvProblem { batch: 8, c_in: 16, c_out: 16, h: 18, w: 18, r: 3 }),
        ("wide", ConvProblem { batch: 8, c_in: 64, c_out: 32, h: 14, w: 14, r: 3 }),
        ("fivebyfive", ConvProblem { batch: 8, c_in: 16, c_out: 32, h: 15, w: 15, r: 5 }),
    ];
    for (name, p) in &specs {
        svc.register(name, *p, Tensor4::random(p.weight_shape(), 11));
        println!(
            "registered '{name}' -> {}",
            svc.layer(name).unwrap().algo.name()
        );
    }

    // 120 requests in randomized layer order, ticking the deadline poller
    let mut rng = Rng::new(2024);
    let mut answered = 0usize;
    let total = 120u64;
    for id in 0..total {
        let (name, p) = specs[rng.below(specs.len())];
        let x = Tensor4::random([1, p.c_in, p.h, p.w], id);
        answered += svc.submit(ConvRequest::new(id, name, x)).unwrap().len();
        if id % 16 == 0 {
            std::thread::sleep(Duration::from_millis(3));
            answered += svc.tick().len();
        }
    }
    answered += svc.flush().len();
    assert_eq!(answered as u64, total);

    let snap = svc.metrics.snapshot();
    println!("\nserved {answered} requests");
    println!("batches executed : {}", snap.batches);
    println!("mean batch size  : {:.2}", snap.mean_batch);
    println!("latency p50      : {:.2} ms", snap.p50_ms);
    println!("latency p95      : {:.2} ms", snap.p95_ms);
    println!("latency max      : {:.2} ms", snap.max_ms);
}
