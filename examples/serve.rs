//! Serving demo: a steady stream of mixed-layer convolution requests
//! through the batching coordinator, with latency metrics — the
//! "coordinator as a service" view of the L3 layer, on the v2 API:
//! layers are addressed by `LayerId` handles, submits return `Ticket`s,
//! and each caller claims exactly its own responses.
//!
//! `cargo run --release --example serve`

use fftconv::conv::{ConvProblem, Tensor4};
use fftconv::coordinator::{ConvRequest, ConvService, LayerId};
use fftconv::model::machine::probe_host;
use fftconv::util::Rng;
use std::time::Duration;

fn main() {
    let host = probe_host();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut svc = ConvService::builder(host)
        .workers(workers)
        .max_batch(8)
        .max_wait(Duration::from_millis(2))
        .build();

    // three registered layers of different shapes
    let specs = [
        ("small", ConvProblem::unit(8, 16, 16, 18, 18, 3)),
        ("wide", ConvProblem::unit(8, 64, 32, 14, 14, 3)),
        ("fivebyfive", ConvProblem::unit(8, 16, 32, 15, 15, 5)),
    ];
    let handles: Vec<LayerId> = specs
        .iter()
        .map(|(name, p)| {
            let id = svc
                .register(name, *p, Tensor4::random(p.weight_shape(), 11))
                .expect("fresh name, matching weights");
            println!(
                "registered '{name}' -> {} (handle {})",
                svc.layer(id).unwrap().algo.name(),
                id.index()
            );
            id
        })
        .collect();

    // 120 requests in randomized layer order, ticking the deadline
    // poller; tickets accumulate and are claimed at the end
    let mut rng = Rng::new(2024);
    let total = 120usize;
    let mut tickets = Vec::with_capacity(total);
    for i in 0..total {
        let which = rng.below(specs.len());
        let p = specs[which].1;
        let x = Tensor4::random([1, p.c_in, p.h, p.w], i as u64);
        let req = ConvRequest::new(handles[which], x).expect("single image");
        tickets.push(svc.submit(req).expect("registered layer"));
        if i % 16 == 0 {
            std::thread::sleep(Duration::from_millis(3));
            svc.tick();
        }
    }
    svc.flush();

    // every ticket resolves to exactly its own response
    let mut answered = 0usize;
    for t in &tickets {
        answered += usize::from(svc.take(*t).is_some());
    }
    assert_eq!(answered, total);
    assert_eq!(svc.unclaimed(), 0);

    let snap = svc.metrics.snapshot();
    println!("\nserved {answered} requests");
    println!("batches executed : {}", snap.batches);
    println!("mean batch size  : {:.2}", snap.mean_batch);
    println!("latency p50      : {:.2} ms", snap.p50_ms);
    println!("latency p95      : {:.2} ms", snap.p95_ms);
    println!("latency max      : {:.2} ms", snap.max_ms);
}
