//! Model-driven algorithm selection (the paper's §5 put to work): for
//! every distinct VGG/AlexNet layer and a sweep of machines, print which
//! method + tile size the Roofline model picks — reproducing the paper's
//! observations that (a) the winner depends on (layer, CMR, cache) and
//! (b) optimal FFT tiles are often not powers of two (27, 25, 21, 31...).
//!
//! `cargo run --release --example autotune`

use fftconv::model::machine::{probe_host, TABLE1};
use fftconv::model::select::{best_tiles_per_method, select};
use fftconv::nets::paper_layers;
use fftconv::util::bench::Table;

fn main() {
    let machines = [
        TABLE1[0].clone(), // KNL, CMR 11
        TABLE1[3].clone(), // Xeon Gold, CMR 24
        TABLE1[9].clone(), // i9 @51GB/s, CMR 41
        probe_host(),
    ];

    let mut table = Table::new(
        "model-chosen algorithm per (layer, machine)",
        &["layer", "machine", "choice", "tile m", "t", "pred ms"],
    );
    for layer in paper_layers() {
        for mach in &machines {
            let c = select(&layer.shape, mach);
            table.row(vec![
                layer.name.to_string(),
                mach.name.chars().take(24).collect(),
                c.method.name().to_string(),
                c.m.to_string(),
                (c.m + layer.shape.r - 1).to_string(),
                format!("{:.2}", c.predicted * 1e3),
            ]);
        }
    }
    table.emit("autotune_choices");

    // the paper's tile-size observation, on the Xeon Gold
    let gold = &TABLE1[3];
    let mut tiles = Table::new(
        "optimal Regular-FFT transform sizes t on Xeon Gold (paper: 27, 25, 21, 16, 9, 31, 15)",
        &["layer", "t (ours)", "power of two?"],
    );
    for layer in paper_layers() {
        let per = best_tiles_per_method(&layer.shape, gold);
        let fft = per
            .iter()
            .find(|c| c.method == fftconv::model::stages::Method::RegularFft)
            .unwrap();
        let t = fft.m + layer.shape.r - 1;
        tiles.row(vec![
            layer.name.to_string(),
            t.to_string(),
            if t.is_power_of_two() { "yes" } else { "NO" }.to_string(),
        ]);
    }
    tiles.emit("autotune_fft_tiles");
}
