"""Numerical-accuracy reproduction of the paper's §4 footnote 2.

Claims under test:
* Winograd error grows (exponentially) with tile size; at 6x6 it is
  comparable to direct convolution, at 8x8 it degrades by ~2-3 orders.
* FFT error stays flat (paper: <= 2.88e-7 regardless of tile size).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def relative_error(method: str, m: int, r: int = 3, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 8, 18, 18)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 8, r, r)), jnp.float32)
    got = model.METHODS[method](x, w, m)
    want = ref.direct_conv(
        jnp.asarray(x, jnp.float64), jnp.asarray(w, jnp.float64)
    )
    num = float(jnp.abs(jnp.asarray(got, jnp.float64) - want).max())
    den = float(jnp.abs(want).max())
    return num / den


class TestWinogradErrorGrowth:
    def test_error_grows_with_tile_size(self):
        errs = [relative_error("winograd", m) for m in (2, 4, 6, 8)]
        # monotone-ish growth: each jump of 2 in m should not shrink error
        assert errs[1] > errs[0] * 0.5
        assert errs[3] > errs[0] * 10, errs  # 8x8 clearly worse than 2x2

    def test_small_tiles_accurate(self):
        # F(4^2, 3^2) (6x6 transform) is the vendor-standard accurate config
        assert relative_error("winograd", 4) < 1e-4

    def test_large_tiles_inaccurate(self):
        # F(8^2, 3^2) (10x10 transform) shows the instability the paper
        # cites as the reason vendors cap Winograd at 6x6 transforms.
        assert relative_error("winograd", 8) > relative_error("winograd", 2)


class TestFFTErrorFlat:
    @pytest.mark.parametrize("method", ["regular_fft", "gauss_fft"])
    def test_error_flat_across_tiles(self, method):
        errs = [relative_error(method, m) for m in (2, 4, 8, 12)]
        assert max(errs) < 5e-6, errs  # flat and tiny, per the paper
        assert max(errs) / (min(errs) + 1e-12) < 50  # no exponential growth

    def test_fft_beats_winograd_at_large_tiles(self):
        assert relative_error("regular_fft", 8) < relative_error("winograd", 8)
