"""AOT path tests: HLO text emission, manifest schema, artifact liveness.

These tests re-lower one small graph (cheap) and sanity-check the emitted
interchange format; full execution of the artifacts is covered on the rust
side (rust/tests/pjrt_artifacts.rs).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestHloEmission:
    def test_small_layer_lowers_to_hlo_text(self):
        entry = dict(name="t", method="winograd", m=2, x=(1, 2, 8, 8), w=(2, 2, 3, 3))
        text = aot.lower_layer(entry)
        assert "ENTRY" in text and "HloModule" in text
        # interpret-mode pallas must not leave custom-calls the CPU
        # plugin can't execute
        assert "mosaic" not in text.lower()

    def test_layer_out_shape(self):
        entry = dict(name="t", method="direct", m=0, x=(1, 2, 8, 8), w=(2, 2, 3, 3))
        assert aot.layer_out_shape(entry) == (1, 2, 6, 6)

    def test_convnet_weight_shapes(self):
        shapes = aot.convnet_weight_shapes()
        ch = aot.CONVNET["channels"]
        assert len(shapes) == len(ch) - 1
        assert all(s[0] == ch[i + 1] and s[1] == ch[i] for i, s in enumerate(shapes))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_schema(self):
        man = self.manifest()
        assert man["artifacts"], "empty manifest"
        for a in man["artifacts"]:
            assert set(a) >= {"name", "kind", "method", "m", "inputs", "output", "file"}
            assert a["kind"] in ("layer", "convnet")

    def test_files_exist_and_parse(self):
        man = self.manifest()
        for a in man["artifacts"]:
            p = os.path.join(ART_DIR, a["file"])
            assert os.path.exists(p), a["file"]
            head = open(p).read(200)
            assert "HloModule" in head

    def test_all_methods_covered(self):
        methods = {a["method"] for a in self.manifest()["artifacts"]}
        assert methods >= {"direct", "winograd", "regular_fft", "gauss_fft"}
