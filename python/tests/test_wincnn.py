"""Tests for the Cook-Toom / Winograd matrix generator (wincnn substitute)."""

import numpy as np
import pytest
from fractions import Fraction

from hypothesis import given, settings, strategies as st

from compile import wincnn


class TestInterpolationPoints:
    def test_first_points_match_wincnn_schedule(self):
        pts = wincnn.interpolation_points(6)
        assert pts == [
            Fraction(0),
            Fraction(1),
            Fraction(-1),
            Fraction(2),
            Fraction(-2),
            Fraction(1, 2),
        ]

    def test_points_distinct(self):
        pts = wincnn.interpolation_points(12)
        assert len(set(pts)) == 12

    @given(st.integers(min_value=1, max_value=14))
    def test_count(self, n):
        assert len(wincnn.interpolation_points(n)) == n


class TestCookToomExact:
    def test_f23_known_shape(self):
        AT, G, BT = wincnn.cook_toom_matrices(2, 3)
        assert len(AT) == 2 and len(AT[0]) == 4
        assert len(G) == 4 and len(G[0]) == 3
        assert len(BT) == 4 and len(BT[0]) == 4

    def test_f23_correlation_identity_exact(self):
        AT, G, BT = wincnn.cook_toom_matrices(2, 3)
        d = [Fraction(3), Fraction(-1), Fraction(4), Fraction(2)]
        g = [Fraction(1), Fraction(5), Fraction(-2)]
        Gg = [sum(G[i][j] * g[j] for j in range(3)) for i in range(4)]
        Bd = [sum(BT[i][j] * d[j] for j in range(4)) for i in range(4)]
        prod = [a * b for a, b in zip(Gg, Bd)]
        y = [sum(AT[k][i] * prod[i] for i in range(4)) for k in range(2)]
        ref = [sum(d[k + j] * g[j] for j in range(3)) for k in range(2)]
        assert y == ref  # exact rational equality

    @pytest.mark.parametrize("m,r", [(2, 3), (3, 3), (4, 3), (5, 3), (6, 3),
                                     (2, 5), (3, 5), (4, 4), (6, 2), (7, 3)])
    def test_identity_float(self, m, r):
        AT, G, BT = wincnn.winograd_matrices(m, r)
        rng = np.random.default_rng(42)
        d = rng.standard_normal(m + r - 1)
        g = rng.standard_normal(r)
        y = AT @ ((G @ g) * (BT @ d))
        ref = np.array([np.dot(d[i : i + r], g) for i in range(m)])
        np.testing.assert_allclose(y, ref, atol=1e-8)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=8),
        r=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_identity_property(self, m, r, seed):
        AT, G, BT = wincnn.winograd_matrices(m, r)
        rng = np.random.default_rng(seed)
        d = rng.standard_normal(m + r - 1)
        g = rng.standard_normal(r)
        y = AT @ ((G @ g) * (BT @ d))
        ref = np.array([np.dot(d[i : i + r], g) for i in range(m)])
        np.testing.assert_allclose(y, ref, atol=1e-6)

    def test_bad_args_raise(self):
        with pytest.raises(ValueError):
            wincnn.cook_toom_matrices(0, 3)


class TestFlopCounts:
    def test_counts_positive_and_growing(self):
        prev = 0
        for m in range(2, 8):
            c = wincnn.transform_flops(m, 3)
            assert c["input"] > 0 and c["kernel"] > 0 and c["output"] > 0
            assert c["input"] > prev  # larger tiles cost more
            prev = c["input"]

    def test_kernel_cheaper_than_input(self):
        # G is t x r (skinnier than B^T, t x t) so kernel transforms cost less
        for m in (2, 4, 6):
            c = wincnn.transform_flops(m, 3)
            assert c["kernel"] < c["input"]
