"""Pallas kernels vs the pure-jnp oracle: the core L1 correctness signal.

Each stage kernel is validated in isolation against its einsum/fft
counterpart, and the composed layer graphs are validated against
``lax.conv`` over a hypothesis-driven shape sweep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, wincnn
from compile.kernels import direct as kdirect
from compile.kernels import fft as kfft
from compile.kernels import ref
from compile.kernels import winograd as kwino


def rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape), dtype)


class TestTiling:
    @pytest.mark.parametrize("h,w,m,r", [(12, 12, 4, 3), (13, 11, 4, 3),
                                         (14, 14, 2, 5), (9, 16, 6, 3)])
    def test_extract_assemble_roundtrip_on_identity_kernel(self, h, w, m, r):
        # Convolving with the delta kernel must reproduce the input crop.
        x = rand((2, 3, h, w), seed=1)
        delta = np.zeros((3, 3, r, r), np.float32)
        for c in range(3):
            delta[c, c, 0, 0] = 1.0
        y = ref.winograd_conv_ref(x, jnp.asarray(delta), m)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x[:, :, : h - r + 1, : w - r + 1]), atol=1e-4
        )

    def test_num_tiles(self):
        assert ref.num_tiles(12, 4, 3) == 3  # (12-2)/4 -> ceil(2.5) = 3
        assert ref.num_tiles(226, 6, 3) == 38


class TestWinogradKernels:
    @pytest.mark.parametrize("m,r", [(2, 3), (4, 3), (6, 3), (3, 5)])
    def test_input_transform_matches_einsum(self, m, r):
        t = m + r - 1
        x = rand((7, t, t), seed=2)
        _, _, BT = wincnn.winograd_matrices(m, r)
        BTj = jnp.asarray(BT, jnp.float32)
        want = jnp.einsum("ij,njk,lk->nil", BTj, x, BTj)
        got = kwino.input_transform(x, m=m, r=r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)

    @pytest.mark.parametrize("m,r", [(2, 3), (4, 3), (3, 5)])
    def test_kernel_transform_matches_einsum(self, m, r):
        x = rand((5, r, r), seed=3)
        _, G, _ = wincnn.winograd_matrices(m, r)
        Gj = jnp.asarray(G, jnp.float32)
        want = jnp.einsum("ij,njk,lk->nil", Gj, x, Gj)
        got = kwino.kernel_transform(x, m=m, r=r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)

    @pytest.mark.parametrize("m,r", [(2, 3), (4, 3), (3, 5)])
    def test_output_transform_matches_einsum(self, m, r):
        t = m + r - 1
        x = rand((9, t, t), seed=4)
        AT, _, _ = wincnn.winograd_matrices(m, r)
        ATj = jnp.asarray(AT, jnp.float32)
        want = jnp.einsum("ij,njk,lk->nil", ATj, x, ATj)
        got = kwino.output_transform(x, m=m, r=r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)

    def test_tuple_gemm_matches_matmul(self):
        u, v = rand((6, 8, 5), seed=5), rand((6, 5, 4), seed=6)
        got = kwino.tuple_gemm(u, v)
        want = jnp.einsum("pnc,pck->pnk", u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)

    def test_tuple_gemm_pads_odd_n(self):
        u, v = rand((3, 7, 5), seed=7), rand((3, 5, 2), seed=8)
        got = kwino.tuple_gemm(u, v)
        want = jnp.einsum("pnc,pck->pnk", u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-4)


class TestFFTKernels:
    @pytest.mark.parametrize("t", [4, 5, 6, 8, 9, 11, 16])
    def test_rfft2_matches_jnp(self, t):
        x = rand((5, t, t), seed=9)
        zr, zi = kfft.rfft2(x, t=t)
        want = jnp.fft.fft2(x)[:, : kfft.half_len(t), :]
        np.testing.assert_allclose(np.asarray(zr), np.asarray(want.real),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(zi), np.asarray(want.imag),
                                   atol=1e-3, rtol=1e-3)

    @pytest.mark.parametrize("t,r", [(6, 3), (8, 3), (7, 5)])
    def test_rfft2_implicit_zero_padding(self, t, r):
        w = rand((4, r, r), seed=10)
        zr, zi = kfft.rfft2(w, t=t, pad=True)
        wp = jnp.pad(w, ((0, 0), (0, t - r), (0, t - r)))
        want = jnp.fft.fft2(wp)[:, : kfft.half_len(t), :]
        np.testing.assert_allclose(np.asarray(zr), np.asarray(want.real),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(zi), np.asarray(want.imag),
                                   atol=1e-3, rtol=1e-3)

    @pytest.mark.parametrize("t,r", [(6, 3), (9, 4), (8, 3)])
    def test_irfft2_valid_prunes_correctly(self, t, r):
        m = t - r + 1
        x = rand((3, t, t), seed=11)
        z = jnp.fft.fft2(x)[:, : kfft.half_len(t), :]
        y = kfft.irfft2_valid(jnp.real(z), jnp.imag(z), t=t, m=m, r=r)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x)[:, r - 1 :, r - 1 :], atol=1e-4
        )

    def test_tuple_cgemm_matches_complex_matmul(self):
        ur, ui = rand((4, 6, 5), seed=12), rand((4, 6, 5), seed=13)
        vr, vi = rand((4, 5, 3), seed=14), rand((4, 5, 3), seed=15)
        zr, zi = kfft.tuple_cgemm(ur, ui, vr, vi)
        want = jnp.einsum("pnc,pck->pnk", ur + 1j * ui, vr + 1j * vi)
        np.testing.assert_allclose(np.asarray(zr), np.asarray(want.real), atol=1e-4)
        np.testing.assert_allclose(np.asarray(zi), np.asarray(want.imag), atol=1e-4)

    def test_gauss_gemm_equals_cgemm(self):
        ur, ui = rand((4, 6, 5), seed=16), rand((4, 6, 5), seed=17)
        vr, vi = rand((4, 5, 3), seed=18), rand((4, 5, 3), seed=19)
        us = kfft.gauss_augment_u(ur, ui)
        vd, vs = kfft.gauss_augment_v(vr, vi)
        zr_g, zi_g = kfft.tuple_gauss_gemm(ur, ui, us, vr, vd, vs)
        zr_c, zi_c = kfft.tuple_cgemm(ur, ui, vr, vi)
        np.testing.assert_allclose(np.asarray(zr_g), np.asarray(zr_c), atol=1e-4)
        np.testing.assert_allclose(np.asarray(zi_g), np.asarray(zi_c), atol=1e-4)


class TestDirectKernel:
    @pytest.mark.parametrize("r", [1, 3, 5])
    def test_direct_matches_lax(self, r):
        x, w = rand((2, 3, 10, 10), seed=20), rand((4, 3, r, r), seed=21)
        got = kdirect.direct_conv(x, w)
        want = ref.direct_conv(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


class TestComposedLayers:
    """Full layer graphs vs lax.conv — the headline correctness check."""

    @pytest.mark.parametrize("method", ["winograd", "regular_fft", "gauss_fft"])
    @pytest.mark.parametrize("m,r", [(2, 3), (4, 3), (6, 3), (2, 5), (4, 5)])
    def test_layer_matches_direct(self, method, m, r):
        x, w = rand((2, 3, 14, 14), seed=22), rand((4, 3, r, r), seed=23)
        got = model.METHODS[method](x, w, m)
        want = ref.direct_conv(x, w)
        tol = 5e-4 if method == "winograd" and m >= 6 else 1e-4
        assert float(jnp.abs(got - want).max()) < tol

    @settings(max_examples=8, deadline=None)
    @given(
        method=st.sampled_from(["winograd", "regular_fft", "gauss_fft"]),
        b=st.integers(1, 3),
        c=st.integers(1, 6),
        k=st.integers(1, 6),
        hw=st.integers(8, 18),
        m=st.integers(2, 6),
        seed=st.integers(0, 2**31),
    )
    def test_layer_shape_sweep(self, method, b, c, k, hw, m, seed):
        r = 3
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((b, c, hw, hw)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, c, r, r)), jnp.float32)
        got = model.METHODS[method](x, w, m)
        want = ref.direct_conv(x, w)
        assert got.shape == want.shape
        scale = float(jnp.abs(want).max()) + 1e-6
        assert float(jnp.abs(got - want).max()) / scale < 1e-3

    def test_non_square_images(self):
        x, w = rand((1, 2, 12, 17), seed=24), rand((3, 2, 3, 3), seed=25)
        for method in ("winograd", "regular_fft", "gauss_fft"):
            got = model.METHODS[method](x, w, 4)
            want = ref.direct_conv(x, w)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=5e-4, rtol=1e-3)
