"""Tests for the L2 layer graphs, net catalogs, and the e2e ConvNet."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape), jnp.float32)


class TestLayerCatalog:
    def test_vgg_layer_count_and_names(self):
        layers = model.vgg_layers()
        assert [l.name for l in layers] == [
            "vgg1.2", "vgg2.1", "vgg2.2", "vgg3.1",
            "vgg3.2", "vgg4.1", "vgg4.2", "vgg5.1",
        ]
        assert all(l.kernel == 3 for l in layers)

    def test_alexnet_layers(self):
        layers = model.alexnet_layers()
        assert [l.name for l in layers] == [
            "alexnet2", "alexnet3", "alexnet4", "alexnet5"
        ]
        assert layers[0].kernel == 5  # the 5x5 layer LIBXSMM/MKL-DNN can't run

    def test_out_size(self):
        l = model.vgg_layers()[0]
        assert l.out_size == 224  # padded 226 - 3 + 1

    def test_total_12_distinct_layers(self):
        assert len(model.all_layers()) == 12  # paper: "12 layers" benchmark


class TestConvnetForward:
    @pytest.mark.parametrize("method", ["winograd", "regular_fft", "gauss_fft"])
    def test_convnet_matches_direct_chain(self, method):
        cfg = dict(x=(1, 4, 16, 16), channels=[4, 6, 4], r=3, m=4)
        x = rand(cfg["x"], seed=1)
        weights = [
            rand((cfg["channels"][i + 1], cfg["channels"][i], 3, 3), seed=2 + i)
            for i in range(len(cfg["channels"]) - 1)
        ]
        got = model.convnet_forward(x, weights, method, cfg["m"])
        want = x
        for i, w in enumerate(weights):
            want = ref.direct_conv(want, w)
            if i + 1 < len(weights):
                want = jax.nn.relu(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=1e-3)

    def test_convnet_output_shape(self):
        x = rand((1, 4, 16, 16))
        weights = [rand((6, 4, 3, 3), seed=5), rand((4, 6, 3, 3), seed=6)]
        y = model.convnet_forward(x, weights, "winograd", 4)
        assert y.shape == (1, 4, 12, 12)


class TestGemmOperandPlumbing:
    """The tile-major <-> GEMM-operand reshapes must be exact inverses."""

    def test_u_operand_roundtrip(self):
        b, c, nh, nw, t = 2, 3, 2, 2, 4
        tiles = rand((b * c * nh * nw, t, t), seed=7)
        u = model._gemm_operand_u(tiles, (b, c, nh, nw), t * t)
        assert u.shape == (t * t, b * nh * nw, c)
        # element check: U[p, b*nh*nw_idx, c] == tiles[(b,c,n) flat, p]
        un = np.asarray(u)
        tn = np.asarray(tiles).reshape(b, c, nh * nw, t * t)
        for p in (0, 5, t * t - 1):
            for bi in range(b):
                for n in range(nh * nw):
                    for ci in range(c):
                        assert un[p, bi * nh * nw + n, ci] == pytest.approx(
                            tn[bi, ci, n, p]
                        )

    def test_z_result_roundtrip(self):
        b, k, nh, nw, s0, s1 = 2, 3, 2, 2, 4, 3
        z = rand((s0 * s1, b * nh * nw, k), seed=8)
        zt = model._from_gemm_result(z, (b, 0, nh, nw), k, s0, s1)
        assert zt.shape == (b * k * nh * nw, s0, s1)
        zn = np.asarray(z).reshape(s0, s1, b, nh * nw, k)
        ztn = np.asarray(zt).reshape(b, k, nh * nw, s0, s1)
        assert ztn[1, 2, 3, 2, 1] == pytest.approx(zn[2, 1, 1, 3, 2])
