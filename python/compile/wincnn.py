"""Cook-Toom (Winograd) transform-matrix generator over exact rationals.

This is the in-repo substitute for Lavin's ``wincnn`` (paper ref. [7]): it
produces the A^T, B^T (referred to as ``AT``/``BT``) and G matrices of the
minimal filtering algorithm F(m, r)

    y = A^T [ (G g) . (B^T d) ]

for arbitrary output size ``m`` and filter size ``r`` using exact
``fractions.Fraction`` arithmetic, so the float matrices handed to the
Pallas kernels are correctly rounded.

Construction (Vincent et al. 2017; Blahut, "Fast Algorithms for Signal
Processing"): choose n = m + r - 2 distinct interpolation points
p_0..p_{n-1} plus the "point at infinity".  With the Vandermonde-ish
matrices below, valid *correlation* (the ConvNet convolution, no filter
flip) of a length-(m+r-1) signal d with a length-r filter g is computed
exactly.  The point schedule matches wincnn's: 0, 1, -1, 2, -2, 1/2, -1/2,
3, -3, 1/3, ... which empirically minimizes the magnitude of matrix
entries and therefore the floating-point error.
"""

from __future__ import annotations

from fractions import Fraction
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "interpolation_points",
    "cook_toom_matrices",
    "winograd_matrices",
    "transform_flops",
]


def interpolation_points(n: int) -> List[Fraction]:
    """First ``n`` points of the wincnn schedule 0, 1, -1, 2, -2, 1/2, ...

    Points must be distinct; the schedule interleaves integers and their
    reciprocals with alternating signs, which keeps the Vandermonde system
    well-conditioned for the small n (<= ~10) used by Winograd convolution.
    """
    pts: List[Fraction] = [Fraction(0)]
    k = 1
    while len(pts) < n:
        group = [Fraction(k), Fraction(-k)]
        if k > 1:
            group += [Fraction(1, k), Fraction(-1, k)]
        for p in group:
            if len(pts) < n and p not in pts:
                pts.append(p)
        k += 1
    return pts[:n]


def _poly_mul(a: Sequence[Fraction], b: Sequence[Fraction]) -> List[Fraction]:
    out = [Fraction(0)] * (len(a) + len(b) - 1)
    for i, ai in enumerate(a):
        for j, bj in enumerate(b):
            out[i + j] += ai * bj
    return out


def _lagrange_basis(points: Sequence[Fraction]) -> Tuple[List[List[Fraction]], List[Fraction]]:
    """Return (numerator polys N_i, denominators d_i) of the Lagrange basis.

    L_i(x) = N_i(x) / d_i with N_i(x) = prod_{j != i} (x - p_j) and
    d_i = prod_{j != i} (p_i - p_j).
    """
    n = len(points)
    numers: List[List[Fraction]] = []
    denoms: List[Fraction] = []
    for i in range(n):
        poly = [Fraction(1)]
        denom = Fraction(1)
        for j in range(n):
            if j == i:
                continue
            poly = _poly_mul(poly, [-points[j], Fraction(1)])
            denom *= points[i] - points[j]
        numers.append(poly)
        denoms.append(denom)
    return numers, denoms


def cook_toom_matrices(m: int, r: int):
    """Exact A^T (m x t), G (t x r), B^T (t x t) for F(m, r), t = m + r - 1.

    Returned as nested lists of ``Fraction``.  Satisfies, for all d, g:

        A^T [ (G g) . (B^T d) ] == valid_correlation(d, g)
    """
    if m < 1 or r < 1:
        raise ValueError("m and r must be >= 1")
    t = m + r - 1
    n = t - 1  # finite interpolation points; last row handles x -> inf
    pts = interpolation_points(n)

    # G: evaluate the filter polynomial g(x) = sum g_k x^k at each point.
    #    Row i (finite point p_i): [1, p_i, p_i^2, ..., p_i^{r-1}]
    #    Last row (infinity):      [0, ..., 0, 1]  (leading coefficient)
    G = [[pts[i] ** k for k in range(r)] for i in range(n)]
    G.append([Fraction(0)] * (r - 1) + [Fraction(1)])

    # B^T: evaluate the *data* polynomial, but composed with the Lagrange
    # scaling so that the element-wise product corresponds to polynomial
    # multiplication followed by interpolation.  Using the standard
    # construction: B^T row i evaluates d(x) at p_i times the inverse
    # denominator structure.  We fold all denominators into B^T so that G
    # and A^T keep small entries (wincnn's convention folds them into B^T
    # via the scaled Lagrange numerators).
    #
    # Let M(x) = prod_j (x - p_j) (degree n).  The full product
    # s(x) = d(x) g(x) has degree t + r - 2 >= n; write
    #   s(x) = q(x) M(x) + rem(x).
    # Interpolation recovers rem from the n point-values; the
    # leading-coefficient (infinity) term supplies q's contribution.
    # The valid-correlation outputs are linear functionals of s's
    # coefficients, assembled by A^T.
    #
    # Concretely (Blahut / Vincent et al.):
    #   BT[i]  = coefficients of N_i(x) / d_i         (degree <= n)  -> but
    # we instead use the transpose-free standard form used by wincnn:
    #   AT[k][i] = p_i^k * (for finite i), AT[k][n] = [x^{m-1}] handling.
    # To keep the code auditable we *derive* B^T numerically-exactly by
    # solving the defining identity instead of hand-deriving each matrix:
    # see _solve_bt below.
    AT = [[pts[i] ** k for i in range(n)] + [Fraction(0)] for k in range(m)]
    AT[m - 1][n] = Fraction(1)

    BT = _solve_bt(m, r, pts, AT, G)
    return AT, G, BT


def _solve_bt(m: int, r: int, pts: Sequence[Fraction], AT, G) -> List[List[Fraction]]:
    """Solve for B^T from the defining identity of F(m, r).

    For F(m,r) with t = m+r-1, the identity
        A^T diag(B^T d) G g == valid_correlation(d, g)
    must hold for all d in Q^t, g in Q^r.  Fixing the canonical bases
    d = e_a, g = e_b gives, for every output row k:
        sum_i AT[k][i] * BT[i][a] * G[i][b] == [a == k + b]
    Because the finite rows of A^T and G are Vandermonde evaluations at
    distinct points, the system determines B^T uniquely; we solve the
    t x t linear system per column a of B^T.

    The unknowns for column a are x_i = BT[i][a], i = 0..t-1.  Equations
    are indexed by (k, b) pairs; there are m*r >= t of them, consistent by
    construction.  We pick t independent ones and verify the rest.
    """
    t = m + r - 1
    rows: List[Tuple[List[Fraction], int]] = []  # (coeff per i, rhs index a == k+b)
    for k in range(m):
        for b in range(r):
            coeff = [AT[k][i] * G[i][b] for i in range(t)]
            rows.append((coeff, k + b))

    # For each column a, solve sum_i coeff[i] x_i = [rhs == a].
    BT_cols: List[List[Fraction]] = []
    for a in range(t):
        mat = [list(c) for c, _ in rows]
        rhs = [Fraction(1) if s == a else Fraction(0) for _, s in rows]
        x = _solve_overdetermined(mat, rhs, t)
        BT_cols.append(x)
    # BT_cols[a][i] = BT[i][a]
    return [[BT_cols[a][i] for a in range(t)] for i in range(t)]


def _solve_overdetermined(mat: List[List[Fraction]], rhs: List[Fraction], n: int) -> List[Fraction]:
    """Gaussian elimination over Q; mat is (rows x n), consistent by design."""
    m_rows = len(mat)
    aug = [mat[i] + [rhs[i]] for i in range(m_rows)]
    row = 0
    pivots = []
    for col in range(n):
        piv = next((r_ for r_ in range(row, m_rows) if aug[r_][col] != 0), None)
        if piv is None:
            raise ValueError("singular system; bad interpolation points")
        aug[row], aug[piv] = aug[piv], aug[row]
        pv = aug[row][col]
        aug[row] = [v / pv for v in aug[row]]
        for r_ in range(m_rows):
            if r_ != row and aug[r_][col] != 0:
                f = aug[r_][col]
                aug[r_] = [a - f * b for a, b in zip(aug[r_], aug[row])]
        pivots.append(col)
        row += 1
        if row == n:
            break
    # verify consistency of remaining rows
    for r_ in range(m_rows):
        lhs = aug[r_][:n]
        if all(v == 0 for v in lhs) and aug[r_][n] != 0:
            raise ValueError("inconsistent system; construction bug")
    return [aug[i][n] for i in range(n)]


def winograd_matrices(m: int, r: int, dtype=np.float64):
    """Float A^T (m x t), G (t x r), B^T (t x t) for F(m, r)."""
    AT, G, BT = cook_toom_matrices(m, r)
    to_np = lambda M: np.array([[float(v) for v in row] for row in M], dtype=dtype)
    return to_np(AT), to_np(G), to_np(BT)


def _count_matrix_ops(M: List[List[Fraction]]) -> Tuple[int, int]:
    """(muls, adds) for a matrix-vector product with constant matrix M.

    Models a scalar transform codelet after trivial strength reduction:
    entries equal to 0 cost nothing; +-1 entries cost no multiply; each
    row costs (nonzeros - 1) additions.  This mirrors how wincnn-generated
    codelets are counted in the paper (before CSE; our rust generator adds
    a CSE pass, see rust/src/winograd/program.rs).
    """
    muls = 0
    adds = 0
    for row in M:
        nz = [v for v in row if v != 0]
        muls += sum(1 for v in nz if abs(v) != 1)
        if nz:
            adds += len(nz) - 1
    return muls, adds


def transform_flops(m: int, r: int) -> dict:
    """FLOPs for 2D input/kernel/output transforms of one tile, F(m^2, r^2).

    A 2D transform X -> M X M^T applies the 1D transform to t columns and
    then to the result's rows.  Returns a dict with keys 'input', 'kernel',
    'output'.
    """
    AT, G, BT = cook_toom_matrices(m, r)
    t = m + r - 1

    def two_d(M, n_in_cols, n_out_rows, in_len):
        muls, adds = _count_matrix_ops(M)
        # first pass: apply to each of n_in_cols columns (length in_len)
        # second pass: apply to each of n_out_rows rows of the intermediate
        return (muls + adds) * (n_in_cols + n_out_rows)

    return {
        "input": two_d(BT, t, t, t),
        "kernel": two_d(G, r, t, r),
        "output": two_d(AT, t, m, t),
    }


if __name__ == "__main__":  # pragma: no cover - manual inspection
    AT, G, BT = winograd_matrices(2, 3)
    print("A^T =\n", AT)
    print("G =\n", G)
    print("B^T =\n", BT)
    for m in range(2, 7):
        print(m, 3, transform_flops(m, 3))
