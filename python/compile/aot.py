"""AOT driver: lower the L2 conv-layer graphs to HLO text artifacts.

Run once at build time (``make artifacts``); Python never executes on the
request path.  Emits, per artifact, ``artifacts/<name>.hlo.txt`` plus a
single ``artifacts/manifest.json`` the rust runtime reads to discover
artifact shapes and entry points.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Artifact catalog.  Shapes are deliberately modest: the CPU PJRT plugin
# executes interpret-mode Pallas HLO, so these prove the three-layer
# composition and provide integration-test vectors; the native rust engine
# carries the full-size paper workloads (see DESIGN.md §3).
SMALL_LAYERS: List[Dict[str, Any]] = [
    # name, method, m, (B, C, H, W), (K, C, r, r)
    dict(name="direct_b2c8", method="direct", m=0, x=(2, 8, 16, 16), w=(4, 8, 3, 3)),
    dict(name="wino_m4_b2c8", method="winograd", m=4, x=(2, 8, 16, 16), w=(4, 8, 3, 3)),
    dict(name="fft_m6_b2c8", method="regular_fft", m=6, x=(2, 8, 16, 16), w=(4, 8, 3, 3)),
    dict(name="gauss_m6_b2c8", method="gauss_fft", m=6, x=(2, 8, 16, 16), w=(4, 8, 3, 3)),
    dict(name="wino_m2_r5", method="winograd", m=2, x=(1, 4, 14, 14), w=(4, 4, 5, 5)),
    dict(name="fft_m11_r5", method="regular_fft", m=11, x=(1, 4, 15, 15), w=(4, 4, 5, 5)),
]

# The e2e ConvNet: three 3x3 conv layers + ReLU, one artifact per method.
CONVNET = dict(x=(1, 8, 20, 20), channels=[8, 12, 8, 4], r=3, m=4)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default elides
    array constants as ``{...}``, which xla_extension 0.5.1's text parser
    silently materializes as zeros — every transform matrix baked into
    the graph (Winograd B^T/G/A^T, DFT matrices) would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_layer(entry: Dict[str, Any]) -> str:
    method, m = entry["method"], entry["m"]
    fn = lambda x, w: model.METHODS[method](x, w, m)
    lowered = jax.jit(fn).lower(_spec(entry["x"]), _spec(entry["w"]))
    return to_hlo_text(lowered)


def convnet_weight_shapes(cfg=CONVNET):
    ch = cfg["channels"]
    r = cfg["r"]
    return [(ch[i + 1], ch[i], r, r) for i in range(len(ch) - 1)]


def lower_convnet(method: str, cfg=CONVNET) -> str:
    m = cfg["m"]
    wspecs = [_spec(s) for s in convnet_weight_shapes(cfg)]

    def fn(x, *weights):
        return model.convnet_forward(x, list(weights), method, m)

    lowered = jax.jit(fn).lower(_spec(cfg["x"]), *wspecs)
    return to_hlo_text(lowered)


def convnet_out_shape(method: str, cfg=CONVNET):
    m = cfg["m"]
    wspecs = [_spec(s) for s in convnet_weight_shapes(cfg)]

    def fn(x, *weights):
        return model.convnet_forward(x, list(weights), method, m)

    return jax.eval_shape(fn, _spec(cfg["x"]), *wspecs).shape


def layer_out_shape(entry):
    fn = lambda x, w: model.METHODS[entry["method"]](x, w, entry["m"])
    return jax.eval_shape(fn, _spec(entry["x"]), _spec(entry["w"])).shape


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="marker path; artifacts land in its directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest: Dict[str, Any] = {"artifacts": []}

    for entry in SMALL_LAYERS:
        if only and entry["name"] not in only:
            continue
        text = lower_layer(entry)
        fname = f"{entry['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            dict(
                name=entry["name"],
                kind="layer",
                method=entry["method"],
                m=entry["m"],
                inputs=[list(entry["x"]), list(entry["w"])],
                output=list(layer_out_shape(entry)),
                file=fname,
            )
        )
        print(f"lowered {entry['name']} -> {fname} ({len(text)} chars)")

    for method in ("winograd", "regular_fft", "gauss_fft", "direct"):
        name = f"convnet_{method}"
        if only and name not in only:
            continue
        text = lower_convnet(method)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            dict(
                name=name,
                kind="convnet",
                method=method,
                m=CONVNET["m"],
                inputs=[list(CONVNET["x"])] + [list(s) for s in convnet_weight_shapes()],
                output=list(convnet_out_shape(method)),
                file=fname,
            )
        )
        print(f"lowered {name} -> {fname} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # the Makefile marker: write the first artifact's text there too
    with open(args.out, "w") as f:
        f.write("# see manifest.json; artifacts are per-graph .hlo.txt files\n")
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
