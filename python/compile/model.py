"""Layer-2 JAX convolution-layer graphs, composed from the L1 Pallas kernels.

Each ``*_conv_layer`` function is the paper's four-phase pipeline (§3):

    input transform -> kernel transform -> element-wise GEMMs -> inverse

built entirely from the Pallas kernels in :mod:`compile.kernels`, plus the
reshapes that realize the paper's data layout (tiles flattened to the
``(P, BN, C)`` / ``(P, C, K)`` tall-skinny GEMM operands of Eqn. 12).

These functions are what :mod:`compile.aot` lowers to HLO text; the rust
runtime executes the artifacts without any Python.  Also defined here:
the distinct conv layers of VGG-16 and AlexNet (Table/Fig. workloads).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import direct as kdirect
from .kernels import fft as kfft
from .kernels import ref
from .kernels import winograd as kwino

# ---------------------------------------------------------------------------
# Layer definitions (the paper's benchmark workloads, §4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One distinct convolutional layer of a benchmark network."""

    name: str
    batch: int
    c_in: int
    c_out: int
    image: int  # square spatial input size (after framework padding)
    kernel: int  # square kernel size r

    @property
    def out_size(self) -> int:
        return self.image - self.kernel + 1


def vgg_layers(batch: int = 64) -> List[ConvLayer]:
    """The distinct VGG-16 conv layers, paper naming (vgg1.2 ... vgg5.1).

    Spatial sizes include VGG's pad=1 (so a 224 input convolves at 226).
    vgg1.1 (C=3) is excluded by the paper's figures; vgg5.2 == vgg5.1.
    """
    mk = lambda nm, c, k, s: ConvLayer(nm, batch, c, k, s + 2, 3)
    return [
        mk("vgg1.2", 64, 64, 224),
        mk("vgg2.1", 64, 128, 112),
        mk("vgg2.2", 128, 128, 112),
        mk("vgg3.1", 128, 256, 56),
        mk("vgg3.2", 256, 256, 56),
        mk("vgg4.1", 256, 512, 28),
        mk("vgg4.2", 512, 512, 28),
        mk("vgg5.1", 512, 512, 14),
    ]


def alexnet_layers(batch: int = 128) -> List[ConvLayer]:
    """The distinct AlexNet conv layers 2-5 (layer 1 is strided, excluded)."""
    return [
        ConvLayer("alexnet2", batch, 64, 192, 27 + 4, 5),
        ConvLayer("alexnet3", batch, 192, 384, 13 + 2, 3),
        ConvLayer("alexnet4", batch, 384, 256, 13 + 2, 3),
        ConvLayer("alexnet5", batch, 256, 256, 13 + 2, 3),
    ]


def all_layers(batch_vgg: int = 64, batch_alex: int = 128) -> List[ConvLayer]:
    return vgg_layers(batch_vgg) + alexnet_layers(batch_alex)


# ---------------------------------------------------------------------------
# Shared tiling plumbing
# ---------------------------------------------------------------------------


def _to_tile_major(tiles: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    """(B, C, nh, nw, t, t) -> ((B*C*nh*nw, t, t), meta)."""
    b, c, nh, nw, t, _ = tiles.shape
    return tiles.reshape(b * c * nh * nw, t, t), (b, c, nh, nw)


def _gemm_operand_u(ut: jax.Array, meta, p: int) -> jax.Array:
    """Transformed tiles (B*C*nh*nw, s0, s1) -> U (P, B*nh*nw, C)."""
    b, c, nh, nw = meta
    s0, s1 = ut.shape[1], ut.shape[2]
    u = ut.reshape(b, c, nh * nw, s0 * s1)
    u = u.transpose(3, 0, 2, 1).reshape(p, b * nh * nw, c)
    return u


def _gemm_operand_v(vt: jax.Array, k: int, c: int, p: int) -> jax.Array:
    """Transformed kernels (K*C, s0, s1) -> V (P, C, K)."""
    v = vt.reshape(k, c, p)
    return v.transpose(2, 1, 0)


def _from_gemm_result(z: jax.Array, meta, k: int, s0: int, s1: int) -> jax.Array:
    """Z (P, B*nh*nw, K) -> pre-output tiles (B*K*nh*nw, s0, s1)."""
    b, _, nh, nw = meta
    z = z.reshape(s0, s1, b, nh * nw, k)
    z = z.transpose(2, 4, 3, 0, 1)  # (b, k, nh*nw, s0, s1)
    return z.reshape(b * k * nh * nw, s0, s1)


def _tiles_to_output(y: jax.Array, meta, k: int, m: int, oh: int, ow: int):
    b, _, nh, nw = meta
    return ref.assemble_tiles(y.reshape(b, k, nh, nw, m, m), oh, ow)


# ---------------------------------------------------------------------------
# The four conv-layer graphs
# ---------------------------------------------------------------------------


def direct_conv_layer(x: jax.Array, w: jax.Array) -> jax.Array:
    """Direct convolution (baseline) through the Pallas direct kernel."""
    return kdirect.direct_conv(x, w)


def winograd_conv_layer(x: jax.Array, w: jax.Array, m: int) -> jax.Array:
    """Winograd F(m^2, r^2) layer over Pallas kernels."""
    b, c, h, wd = x.shape
    k, _, r, _ = w.shape
    t = m + r - 1
    p = t * t

    tiles, meta = _to_tile_major(ref.extract_tiles(x, m, r))
    ut = kwino.input_transform(tiles, m=m, r=r)  # (NT, t, t)
    vt = kwino.kernel_transform(w.reshape(k * c, r, r), m=m, r=r)
    u = _gemm_operand_u(ut, meta, p)
    v = _gemm_operand_v(vt, k, c, p)
    z = kwino.tuple_gemm(u, v)  # (P, BN, K)
    zt = _from_gemm_result(z, meta, k, t, t)
    y = kwino.output_transform(zt, m=m, r=r)  # (NT', m, m)
    return _tiles_to_output(y, meta, k, m, h - r + 1, wd - r + 1)


def _fft_front(x, w, m):
    """Shared forward path of both FFT variants."""
    b, c, h, wd = x.shape
    k, _, r, _ = w.shape
    t = m + r - 1
    th = kfft.half_len(t)
    p = th * t

    tiles, meta = _to_tile_major(ref.extract_tiles(x, m, r))
    ur_t, ui_t = kfft.rfft2(tiles, t=t)  # (NT, th, t) x2
    wf = jnp.flip(w, axis=(-1, -2)).reshape(k * c, r, r)
    vr_t, vi_t = kfft.rfft2(wf, t=t, pad=True)

    u_r = _gemm_operand_u(ur_t, meta, p)
    u_i = _gemm_operand_u(ui_t, meta, p)
    v_r = _gemm_operand_v(vr_t.reshape(k * c, p), k, c, p)
    v_i = _gemm_operand_v(vi_t.reshape(k * c, p), k, c, p)
    return meta, (b, c, h, wd, k, r, t, th, p), (u_r, u_i, v_r, v_i)


def _fft_back(zr, zi, meta, dims):
    b, c, h, wd, k, r, t, th, p = dims
    m = t - r + 1
    zr_t = _from_gemm_result(zr, meta, k, th, t)
    zi_t = _from_gemm_result(zi, meta, k, th, t)
    y = kfft.irfft2_valid(zr_t, zi_t, t=t, m=m, r=r)
    return _tiles_to_output(y, meta, k, m, h - r + 1, wd - r + 1)


def regular_fft_conv_layer(x: jax.Array, w: jax.Array, m: int) -> jax.Array:
    """Regular-FFT 𝔉(m^2, r^2) layer over Pallas kernels."""
    meta, dims, (u_r, u_i, v_r, v_i) = _fft_front(x, w, m)
    zr, zi = kfft.tuple_cgemm(u_r, u_i, v_r, v_i)
    return _fft_back(zr, zi, meta, dims)


def gauss_fft_conv_layer(x: jax.Array, w: jax.Array, m: int) -> jax.Array:
    """Gauss-FFT 𝔊(m^2, r^2) layer: 3 real GEMMs in the element-wise stage."""
    meta, dims, (u_r, u_i, v_r, v_i) = _fft_front(x, w, m)
    u_s = kfft.gauss_augment_u(u_r, u_i)
    v_d, v_s = kfft.gauss_augment_v(v_r, v_i)
    zr, zi = kfft.tuple_gauss_gemm(u_r, u_i, u_s, v_r, v_d, v_s)
    return _fft_back(zr, zi, meta, dims)


METHODS: Dict[str, Callable] = {
    "direct": lambda x, w, m: direct_conv_layer(x, w),
    "winograd": winograd_conv_layer,
    "regular_fft": regular_fft_conv_layer,
    "gauss_fft": gauss_fft_conv_layer,
}


def convnet_forward(x: jax.Array, weights: List[jax.Array], method: str, m: int):
    """A small ConvNet: chained conv layers + ReLU (the e2e PJRT artifact)."""
    fn = METHODS[method]
    for i, w in enumerate(weights):
        x = fn(x, w, m)
        if i + 1 < len(weights):
            x = jax.nn.relu(x)
    return x
