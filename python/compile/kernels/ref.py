"""Pure-jnp correctness oracles for the convolution layer.

Everything in this file is straight-line ``jnp`` — no Pallas — and serves
as the ground truth the Pallas kernels (and, transitively, the rust native
engine and the AOT artifacts) are validated against.

Layer semantics (matches the paper and every ConvNet framework):
"valid" cross-correlation, NCHW activations, KCRS weights:

    out[b, k, i, j] = sum_{c, u, v} x[b, c, i+u, j+v] * w[k, c, u, v]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import wincnn

__all__ = [
    "direct_conv",
    "winograd_conv_ref",
    "fft_conv_ref",
    "extract_tiles",
    "assemble_tiles",
    "num_tiles",
]


def direct_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Valid cross-correlation via lax.conv — the canonical oracle.

    x: (B, C, H, W); w: (K, C, r, r) -> (B, K, H-r+1, W-r+1)
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def num_tiles(size: int, m: int, r: int) -> int:
    """Tiles along one dimension: ceil((size - r + 1) / m)."""
    return -(-(size - r + 1) // m)


def extract_tiles(x: jax.Array, m: int, r: int) -> jax.Array:
    """Overlap-add tiling: (B, C, H, W) -> (B, C, nh, nw, t, t).

    Tiles of size t = m + r - 1 with stride m (overlap r - 1), padding the
    image with zeros on the bottom/right when (H - r + 1) % m != 0 —
    exactly the paper's OLA decomposition (§2.2).
    """
    B, C, H, W = x.shape
    t = m + r - 1
    nh, nw = num_tiles(H, m, r), num_tiles(W, m, r)
    Hp, Wp = (nh - 1) * m + t, (nw - 1) * m + t
    x = jnp.pad(x, ((0, 0), (0, 0), (0, Hp - H), (0, Wp - W)))
    # Gather the t*t strided slices; each is (B, C, nh, nw).
    rows = []
    for u in range(t):
        cols = []
        for v in range(t):
            sl = jax.lax.slice(
                x,
                (0, 0, u, v),
                (B, C, u + (nh - 1) * m + 1, v + (nw - 1) * m + 1),
                (1, 1, m, m),
            )
            cols.append(sl)
        rows.append(jnp.stack(cols, axis=-1))  # (B, C, nh, nw, t)
    return jnp.stack(rows, axis=-2)  # (B, C, nh, nw, t, t)


def assemble_tiles(tiles: jax.Array, out_h: int, out_w: int) -> jax.Array:
    """Inverse of the OLA output split: (B, K, nh, nw, m, m) -> (B, K, H', W').

    Output tiles do not overlap; we reshape and crop the zero-pad remainder.
    """
    B, K, nh, nw, m, _ = tiles.shape
    out = tiles.transpose(0, 1, 2, 4, 3, 5).reshape(B, K, nh * m, nw * m)
    return out[:, :, :out_h, :out_w]


def winograd_conv_ref(x: jax.Array, w: jax.Array, m: int) -> jax.Array:
    """Winograd F(m^2, r^2) conv layer in pure jnp (oracle for the kernels)."""
    B, C, H, W = x.shape
    K, _, r, _ = w.shape
    AT, G, BT = wincnn.winograd_matrices(m, r, dtype=np.float64)
    AT, G, BT = (jnp.asarray(M, dtype=x.dtype) for M in (AT, G, BT))

    tiles = extract_tiles(x, m, r)  # (B,C,nh,nw,t,t)
    # Input transform: B^T d B
    U = jnp.einsum("ij,bcnwjk,lk->bcnwil", BT, tiles, BT)
    # Kernel transform: G g G^T
    V = jnp.einsum("ij,kcjl,ml->kcim", G, w, G)
    # Element-wise stage: contract over C at each of the t^2 positions.
    Z = jnp.einsum("bcnwil,kcil->bknwil", U, V)
    # Output transform: A^T z A
    Y = jnp.einsum("ij,bknwjl,ml->bknwim", AT, Z, AT)
    return assemble_tiles(Y, H - r + 1, W - r + 1)


def fft_conv_ref(x: jax.Array, w: jax.Array, m: int) -> jax.Array:
    """Regular-FFT conv layer in pure jnp via rfft2 (oracle for the kernels).

    Valid correlation == circular convolution with the spatially-flipped,
    zero-padded kernel; the last m x m elements of each t x t circular
    output tile are the valid results (§2.1).
    """
    B, C, H, W = x.shape
    K, _, r, _ = w.shape
    t = m + r - 1

    tiles = extract_tiles(x, m, r)  # (B,C,nh,nw,t,t)
    wf = jnp.flip(w, axis=(-1, -2))
    U = jnp.fft.rfft2(tiles, s=(t, t))  # (B,C,nh,nw,t,th)
    V = jnp.fft.rfft2(wf, s=(t, t))  # (K,C,t,th)
    Z = jnp.einsum("bcnwil,kcil->bknwil", U, V)
    Y = jnp.fft.irfft2(Z, s=(t, t))[..., r - 1 :, r - 1 :]  # last m x m
    return assemble_tiles(Y, H - r + 1, W - r + 1)
