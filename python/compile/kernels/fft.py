"""Layer-1 Pallas kernels for the Regular-FFT and Gauss-FFT stages.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FFTW
codelets are butterfly programs tuned for AVX512 registers.  A butterfly
network is a poor fit for the MXU, so the kernels here express the small
(t <= 32) tile DFTs as *matrix products with precomputed DFT matrices* —
for these sizes the t x t matmul runs on the systolic array at full
utilization, which is the TPU-shaped realization of the same
transform-stage schedule.  The conjugate-symmetric half-spectrum storage
(t x th, th = floor(t/2)+1 along the leading axis) matches the paper's
t * ceil((t+1)/2) accounting.

Complex tensors are carried as separate real/imaginary planes (SoA), the
same layout the native rust engine uses.

Kernels:
* :func:`rfft2`            — implicitly zero-padded forward transform
* :func:`irfft2_valid`     — pruned inverse: only the last m x m outputs
* :func:`tuple_cgemm`      — element-wise stage, complex GEMM (4 real mults)
* :func:`tuple_gauss_gemm` — element-wise stage, Gauss 3-real-mult variant
* :func:`gauss_augment`    — build the (Ur+Ui) / (Vi-Vr) / (Vr+Vi) planes
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_BLOCK = 16


def _pad_to(n: int, b: int) -> int:
    return -(-n // b) * b


def half_len(t: int) -> int:
    """Conjugate-symmetric spectrum length floor(t/2)+1 == ceil((t+1)/2)."""
    return t // 2 + 1


def _dft_mats(t: int, rows: int, cols: int, dtype=np.float32):
    """cos/sin matrices of the forward DFT: W[j,k] = e^{-2 pi i j k / t}."""
    j = np.arange(rows)[:, None]
    k = np.arange(cols)[None, :]
    ang = -2.0 * np.pi * j * k / t
    return np.cos(ang).astype(dtype), np.sin(ang).astype(dtype)


def _idft_col_mats(t: int, m: int, r: int, dtype=np.float32):
    """Inverse-DFT matrices over the *full* complex axis (columns).

    Rows select only the last m outputs (positions r-1 .. t-1): the pruned
    inverse of the paper (§A.4, "only a subset of m x m elements").
    """
    n = (np.arange(m) + r - 1)[:, None]
    k = np.arange(t)[None, :]
    ang = 2.0 * np.pi * n * k / t
    return (np.cos(ang) / t).astype(dtype), (np.sin(ang) / t).astype(dtype)


def _irfft_row_mats(t: int, m: int, r: int, dtype=np.float32):
    """Half-spectrum-to-real inverse matrices (rows), pruned to last m.

    y[n] = sum_{k<th} w_k * (Yr[k] cos(2 pi k n/t) - Yi[k] sin(2 pi k n/t))/t
    with w_k = 2 except w_0 = 1 and, for even t, w_{t/2} = 1.
    """
    th = half_len(t)
    w = np.full(th, 2.0)
    w[0] = 1.0
    if t % 2 == 0:
        w[-1] = 1.0
    n = (np.arange(m) + r - 1)[:, None]
    k = np.arange(th)[None, :]
    ang = 2.0 * np.pi * n * k / t
    cw = (w * np.cos(ang) / t).astype(dtype)
    sw = (w * np.sin(ang) / t).astype(dtype)
    return cw, sw


@functools.partial(jax.jit, static_argnames=("t", "pad"))
def rfft2(x: jax.Array, *, t: int, pad: bool = False) -> tuple[jax.Array, jax.Array]:
    """Implicitly zero-padded 2D forward DFT of real tiles.

    x: (NT, s, s) with s == t (input tiles) or s == r < t (kernels, then
    ``pad=True`` applies implicit zero-padding through sliced DFT
    matrices — no zeros are materialized, matching genfft's padded
    codelets).  Returns (Zr, Zi), each (NT, th, t).
    """
    s = x.shape[1]
    assert pad or s == t
    th = half_len(t)
    ch, sh = _dft_mats(t, th, s)  # half-spectrum rows, s input cols
    ct, st = _dft_mats(t, t, s)  # full complex axis

    def kern(x_ref, ch_ref, sh_ref, ct_ref, st_ref, zr_ref, zi_ref):
        v = x_ref[...]
        chc, shc = ch_ref[...], sh_ref[...]
        ctc, stc = ct_ref[...], st_ref[...]
        # rows: Y = D_h @ x  (complex, x real)
        yr = jnp.einsum("ij,njk->nik", chc, v)
        yi = jnp.einsum("ij,njk->nik", shc, v)
        # cols: Z = Y @ D_t^T
        zr_ref[...] = jnp.einsum("nik,lk->nil", yr, ctc) - jnp.einsum(
            "nik,lk->nil", yi, stc
        )
        zi_ref[...] = jnp.einsum("nik,lk->nil", yr, stc) + jnp.einsum(
            "nik,lk->nil", yi, ctc
        )

    nt = x.shape[0]
    ntp = _pad_to(max(nt, 1), TILE_BLOCK)
    if ntp != nt:
        x = jnp.pad(x, ((0, ntp - nt), (0, 0), (0, 0)))
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: (0, 0))
    mats = tuple(jnp.asarray(M, x.dtype) for M in (ch, sh, ct, st))
    zr, zi = pl.pallas_call(
        kern,
        grid=(ntp // TILE_BLOCK,),
        in_specs=[pl.BlockSpec((TILE_BLOCK, s, s), lambda i: (i, 0, 0))]
        + [whole(M) for M in mats],
        out_specs=[
            pl.BlockSpec((TILE_BLOCK, th, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_BLOCK, th, t), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ntp, th, t), x.dtype),
            jax.ShapeDtypeStruct((ntp, th, t), x.dtype),
        ],
        interpret=True,
    )(x, *mats)
    return zr[:nt], zi[:nt]


@functools.partial(jax.jit, static_argnames=("t", "m", "r"))
def irfft2_valid(zr: jax.Array, zi: jax.Array, *, t: int, m: int, r: int) -> jax.Array:
    """Pruned inverse transform: (NT, th, t) complex -> (NT, m, m) real.

    Inverts the column axis first (full complex iDFT, keeping only the
    last m columns), then the half-spectrum row axis with real-output
    weights — only the valid m x m window is ever computed.
    """
    th = half_len(t)
    bc, bs = _idft_col_mats(t, m, r)  # (m, t)
    cw, sw = _irfft_row_mats(t, m, r)  # (m, th)

    def kern(zr_ref, zi_ref, bc_ref, bs_ref, cw_ref, sw_ref, o_ref):
        vr, vi = zr_ref[...], zi_ref[...]
        bcc, bsc = bc_ref[...], bs_ref[...]
        cwc, swc = cw_ref[...], sw_ref[...]
        # columns: Y = Z @ Bc^T (complex) — (n, th, m)
        yr = jnp.einsum("nik,jk->nij", vr, bcc) - jnp.einsum("nik,jk->nij", vi, bsc)
        yi = jnp.einsum("nik,jk->nij", vr, bsc) + jnp.einsum("nik,jk->nij", vi, bcc)
        # rows: real output from half spectrum — (n, m, m)
        o_ref[...] = jnp.einsum("li,nij->nlj", cwc, yr) - jnp.einsum(
            "li,nij->nlj", swc, yi
        )

    nt = zr.shape[0]
    ntp = _pad_to(max(nt, 1), TILE_BLOCK)
    if ntp != nt:
        zr = jnp.pad(zr, ((0, ntp - nt), (0, 0), (0, 0)))
        zi = jnp.pad(zi, ((0, ntp - nt), (0, 0), (0, 0)))
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: (0, 0))
    mats = tuple(jnp.asarray(M, zr.dtype) for M in (bc, bs, cw, sw))
    out = pl.pallas_call(
        kern,
        grid=(ntp // TILE_BLOCK,),
        in_specs=[
            pl.BlockSpec((TILE_BLOCK, th, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_BLOCK, th, t), lambda i: (i, 0, 0)),
        ]
        + [whole(M) for M in mats],
        out_specs=pl.BlockSpec((TILE_BLOCK, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ntp, m, m), zr.dtype),
        interpret=True,
    )(zr, zi, *mats)
    return out[:nt]


# ---------------------------------------------------------------------------
# Element-wise stage
# ---------------------------------------------------------------------------

def _gemm_block_n(n: int) -> int:
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@jax.jit
def tuple_cgemm(ur, ui, vr, vi):
    """Complex batched GEMM (Regular-FFT element-wise stage).

    (P, N, C) x (P, C, K) -> (P, N, K), 4 real multiplies per complex
    multiply-add pair (§2.3): Zr = UrVr - UiVi, Zi = UrVi + UiVr.
    """
    p, n, _ = ur.shape
    bn = _gemm_block_n(n)

    def kern(ur_ref, ui_ref, vr_ref, vi_ref, zr_ref, zi_ref):
        a, b = ur_ref[...], ui_ref[...]
        c, d = vr_ref[...], vi_ref[...]
        mm = lambda x, y: jnp.einsum("pnc,pck->pnk", x, y)
        zr_ref[...] = mm(a, c) - mm(b, d)
        zi_ref[...] = mm(a, d) + mm(b, c)

    c_dim, k_dim = vr.shape[1], vr.shape[2]
    uspec = pl.BlockSpec((1, bn, c_dim), lambda i, j: (i, j, 0))
    vspec = pl.BlockSpec((1, c_dim, k_dim), lambda i, j: (i, 0, 0))
    ospec = pl.BlockSpec((1, bn, k_dim), lambda i, j: (i, j, 0))
    oshape = jax.ShapeDtypeStruct((p, n, k_dim), ur.dtype)
    return pl.pallas_call(
        kern,
        grid=(p, n // bn),
        in_specs=[uspec, uspec, vspec, vspec],
        out_specs=[ospec, ospec],
        out_shape=[oshape, oshape],
        interpret=True,
    )(ur, ui, vr, vi)


@jax.jit
def gauss_augment_u(ur, ui):
    """Image-side Gauss plane: Us = Ur + Ui (computed during transform)."""
    return ur + ui


@jax.jit
def gauss_augment_v(vr, vi):
    """Kernel-side Gauss planes: (Vd, Vs) = (Vi - Vr, Vr + Vi)."""
    return vi - vr, vr + vi


@jax.jit
def tuple_gauss_gemm(ur, ui, us, vr, vd, vs):
    """Gauss-FFT element-wise stage: 3 real GEMMs per complex GEMM (§2.3).

    tmp1 = (Ur+Ui) Vr;  tmp2 = Ur (Vi-Vr);  tmp3 = Ui (Vr+Vi)
    Zr = tmp1 - tmp3;   Zi = tmp1 + tmp2
    """
    p, n, _ = ur.shape
    bn = _gemm_block_n(n)

    def kern(ur_ref, ui_ref, us_ref, vr_ref, vd_ref, vs_ref, zr_ref, zi_ref):
        mm = lambda x, y: jnp.einsum("pnc,pck->pnk", x, y)
        t1 = mm(us_ref[...], vr_ref[...])
        t2 = mm(ur_ref[...], vd_ref[...])
        t3 = mm(ui_ref[...], vs_ref[...])
        zr_ref[...] = t1 - t3
        zi_ref[...] = t1 + t2

    c_dim, k_dim = vr.shape[1], vr.shape[2]
    uspec = pl.BlockSpec((1, bn, c_dim), lambda i, j: (i, j, 0))
    vspec = pl.BlockSpec((1, c_dim, k_dim), lambda i, j: (i, 0, 0))
    ospec = pl.BlockSpec((1, bn, k_dim), lambda i, j: (i, j, 0))
    oshape = jax.ShapeDtypeStruct((p, n, k_dim), ur.dtype)
    return pl.pallas_call(
        kern,
        grid=(p, n // bn),
        in_specs=[uspec, uspec, uspec, vspec, vspec, vspec],
        out_specs=[ospec, ospec],
        out_shape=[oshape, oshape],
        interpret=True,
    )(ur, ui, us, vr, vd, vs)
