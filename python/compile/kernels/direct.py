"""Layer-1 Pallas kernel for direct convolution (the paper's baseline).

Direct convolution is the MKL-DNN comparator in Figs. 1/6/7.  The kernel
computes one (B-block, K) output plane per grid step by accumulating the
r*r shifted input windows — the classic "shift-and-multiply" direct
method, expressed with matmul-shaped contractions over channels so the
MXU path stays hot on real hardware.

Data contract: x (B, C, H, W), w (K, C, r, r) -> (B, K, H-r+1, W-r+1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=())
def direct_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Valid cross-correlation as a Pallas kernel."""
    b, c, h, wd = x.shape
    k, _, r, _ = w.shape
    oh, ow = h - r + 1, wd - r + 1

    def kern(x_ref, w_ref, o_ref):
        xv = x_ref[...]  # (1, C, H, W)
        wv = w_ref[...]  # (K, C, r, r)
        acc = jnp.zeros((1, k, oh, ow), xv.dtype)
        for u in range(r):
            for v in range(r):
                win = xv[:, :, u : u + oh, v : v + ow]  # (1, C, oh, ow)
                acc = acc + jnp.einsum(
                    "bchw,kc->bkhw", win, wv[:, :, u, v],
                    preferred_element_type=xv.dtype,
                )
        o_ref[...] = acc

    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, h, wd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, c, r, r), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, oh, ow), x.dtype),
        interpret=True,
    )(x, w)
