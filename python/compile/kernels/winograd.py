"""Layer-1 Pallas kernels for the Winograd convolution stages.

Four kernels, mirroring the paper's four computation phases (§3):

* :func:`input_transform`   — ``B^T d B``   per tile
* :func:`kernel_transform`  — ``G g G^T``   per kernel
* :func:`tuple_gemm`        — the element-wise stage: for each of the t^2
  transform positions, a ``(N x C) @ (C x K)`` real GEMM (Eqn. 12)
* :func:`output_transform`  — ``A^T z A``   per pre-output tile

All kernels are matmul-shaped on purpose: on a real TPU each lowers onto
the MXU systolic array; ``BlockSpec`` expresses the HBM->VMEM tile
schedule that the paper expressed with cache blocking.  Kernels are
always instantiated with ``interpret=True`` here because the CPU PJRT
plugin cannot execute Mosaic custom-calls (see DESIGN.md).

Data contracts (tile-major, channel layout flattened by the L2 model):
    input tiles   (NT, t, t)   float32      NT = B*C*nh*nw
    kernels       (NK, r, r)   float32      NK = K*C
    tuple operands U (P, N, C), V (P, C, K) with P = t*t
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import wincnn

# Grid block over the tile axis: how many tiles one kernel instance
# transforms.  16 matches the paper's cache-line interleave factor.
TILE_BLOCK = 16


def _pad_to(n: int, b: int) -> int:
    return -(-n // b) * b


def _sandwich_kernel(x_ref, m_ref, o_ref):
    """o = M x M^T for a block of tiles (the 2D transform as two matmuls).

    The transform matrix is a kernel *input* (Pallas disallows captured
    constants); its BlockSpec pins the whole matrix VMEM-resident.
    """
    x = x_ref[...]
    mat = m_ref[...]
    o_ref[...] = jnp.einsum(
        "ij,njk,lk->nil", mat, x, mat, preferred_element_type=x.dtype
    )


@functools.partial(jax.jit, static_argnames=("m", "r"))
def input_transform(tiles: jax.Array, *, m: int, r: int) -> jax.Array:
    """``B^T d B`` for every tile: (NT, t, t) -> (NT, t, t)."""
    t = m + r - 1
    _, _, BT = wincnn.winograd_matrices(m, r)
    return _tilewise(tiles, jnp.asarray(BT, tiles.dtype), t, t)


@functools.partial(jax.jit, static_argnames=("m", "r"))
def kernel_transform(w: jax.Array, *, m: int, r: int) -> jax.Array:
    """``G g G^T`` for every kernel: (NK, r, r) -> (NK, t, t)."""
    t = m + r - 1
    _, G, _ = wincnn.winograd_matrices(m, r)
    return _tilewise(w, jnp.asarray(G, w.dtype), r, t)


@functools.partial(jax.jit, static_argnames=("m", "r"))
def output_transform(z: jax.Array, *, m: int, r: int) -> jax.Array:
    """``A^T z A`` for every pre-output tile: (NT, t, t) -> (NT, m, m)."""
    t = m + r - 1
    AT, _, _ = wincnn.winograd_matrices(m, r)
    return _tilewise(z, jnp.asarray(AT, z.dtype), t, m)


def _tilewise(x: jax.Array, mat: jax.Array, in_side: int, out_side: int) -> jax.Array:
    """Apply o = M x M^T over (NT, in, in) -> (NT, out, out)."""
    nt = x.shape[0]
    ntp = _pad_to(max(nt, 1), TILE_BLOCK)
    if ntp != nt:
        x = jnp.pad(x, ((0, ntp - nt), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _sandwich_kernel,
        grid=(ntp // TILE_BLOCK,),
        in_specs=[
            pl.BlockSpec((TILE_BLOCK, in_side, in_side), lambda i: (i, 0, 0)),
            pl.BlockSpec((out_side, in_side), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_BLOCK, out_side, out_side), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ntp, out_side, out_side), x.dtype),
        interpret=True,
    )(x, mat)
    return out[:nt]


# ---------------------------------------------------------------------------
# Element-wise stage (real GEMM per transform position)
# ---------------------------------------------------------------------------

def _gemm_block_n(n: int) -> int:
    """Rows of U processed per kernel instance (VMEM tile height)."""
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@jax.jit
def tuple_gemm(u: jax.Array, v: jax.Array) -> jax.Array:
    """Batched real GEMM: (P, N, C) @ (P, C, K) -> (P, N, K).

    One grid step per (position, N-block); V's (C, K) block stays resident
    (the paper keeps the kernel sub-matrix cache-resident, Eqn. 13 — here
    that becomes a VMEM-resident BlockSpec).
    """
    p, n, _ = u.shape
    bn = _gemm_block_n(n)

    def kern(u_ref, v_ref, o_ref):
        o_ref[...] = jnp.einsum(
            "pnc,pck->pnk",
            u_ref[...],
            v_ref[...],
            preferred_element_type=u_ref.dtype,
        )

    return pl.pallas_call(
        kern,
        grid=(p, n // bn),
        in_specs=[
            pl.BlockSpec((1, bn, u.shape[2]), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, v.shape[1], v.shape[2]), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, v.shape[2]), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n, v.shape[2]), u.dtype),
        interpret=True,
    )(u, v)
