#!/usr/bin/env bash
# Tier-1 verify + lint + perf snapshot.
#
#   ./verify.sh          build + tests + clippy + hot-path bench (JSON)
#   ./verify.sh --quick  build + tests only
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo build --release
cargo test -q

# the same suite again with SIMD dispatch pinned to the scalar kernels:
# proves the portable path stays correct (and that the equivalence suite
# in tests/simd_kernels.rs really is comparing against a live baseline).
# This pass includes the whole-network differential suite
# (tests/network_e2e.rs), the random shape sweep (tests/shape_sweep.rs),
# and the async front-end suite (tests/async_frontend.rs), so every
# served network, sampled geometry, and reactor-delivered response is
# diffed against the naive oracle on BOTH the native and the portable
# kernel sets — in --quick mode too.
echo "---- forced-scalar pass (FFTCONV_FORCE_ISA=scalar) ----"
FFTCONV_FORCE_ISA=scalar cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "clippy not installed; skipping lint"
fi

# cross-check the portable (non-x86) build: every x86 intrinsic block —
# transpose kernels, streaming stores, GEMM micro-kernels — must stay
# behind cfg(target_arch) with a scalar path that still compiles
if command -v rustup >/dev/null 2>&1 \
    && rustup target list --installed 2>/dev/null | grep -q '^aarch64-unknown-linux-gnu$'; then
    echo "---- aarch64 cross-check (cargo check) ----"
    cargo check --target aarch64-unknown-linux-gnu
else
    echo "aarch64-unknown-linux-gnu target not installed; skipping cross-check"
fi

# profile warm-start smoke: export a tuning profile from a short serving
# run, import it into a fresh service, and serve again with zero
# re-measurements (examples/profile_warmstart.rs exits non-zero if the
# warm-started run re-measures anything) — runs in --quick mode too
echo "---- profile export -> import -> serve smoke ----"
cargo run --release --example profile_warmstart

if [[ "${1:-}" != "--quick" ]]; then
    # regenerates rust/BENCH_hotpaths.json (the perf trajectory record:
    # VGG-layer single-thread vs stage-parallel, plan cold vs warm, fused
    # vs staged pipelines with predicted DRAM traffic per mode, and the
    # measured-autotuning "tuning" block — analytic vs measured exec pick
    # and disagreement count; schema in docs/ARCHITECTURE.md)
    cargo bench --bench micro_hotpaths
    if [[ -f BENCH_hotpaths.json ]]; then
        echo "---- ISA dispatch + roofline attainment ----"
        grep -E '"(isa|peak_gflops|scalar|avx2|avx512|real_gflops|real_attainment_pct|cgemm_gflops|cgemm_attainment_pct|gauss_gflops|gauss_attainment_pct|vgg_attainment_pct|alexnet_attainment_pct)"' \
            BENCH_hotpaths.json || true
        echo "---- submit path (v2 typed-handle intake) ----"
        grep -E '"(scheduler_batch8_us|submit_path_us)"' BENCH_hotpaths.json || true
        echo "---- fused vs staged summary (BENCH_hotpaths.json) ----"
        grep -E '"(vgg|alexnet)_(staged_ms|fused_ms|fused_speedup|pred_staged_bytes|pred_fused_bytes|panel_tiles|exec_selected)"' \
            BENCH_hotpaths.json || true
        echo "---- tuning: analytic vs measured exec pick ----"
        grep -E '"(analytic|measured|agree|disagreements|staged_ms|fused_ms)"' \
            BENCH_hotpaths.json | tail -12 || true
        echo "---- decay: drift events / expiries / flips ----"
        grep -E '"(policy|rel_tol|drift_events|expiries|remeasurements|flips|shadow_batches|resolved_after)"' \
            BENCH_hotpaths.json || true
        echo "---- transform phase: achieved GB/s vs calibrated ceiling ----"
        grep -E '"(bw_ceiling_gbps|input_ms|output_ms|input_gbps|output_gbps|bw_attainment_pct)"' \
            BENCH_hotpaths.json || true
        echo "---- network serving: per-net totals + arena savings ----"
        grep -E '"(total_ms|interlayer_bytes_saved|slowest_layer)"' \
            BENCH_hotpaths.json || true
        echo "---- shard: replicas / cross-replica hits / warm-start savings ----"
        grep -E '"(replicas|fleet_batches|cross_replica_hits|tuning_entries|warmstart_hits|warmstart_remeasurements_saved)"' \
            BENCH_hotpaths.json || true
    fi
fi

# front-end summary runs in --quick mode too (against the JSON from the
# last full run, if one exists): open-loop throughput, latency quantiles,
# and the 2x-overload shed rate from the reactor + admission-control path
if [[ -f BENCH_hotpaths.json ]]; then
    echo "---- frontend: 2x-overload open loop (img/s, p50/p95/p99, shed) ----"
    grep -E '"(intake_limit|capacity_ips|offered_ips|images_per_sec|p50_ms|p95_ms|p99_ms|shed_rate_pct|p95_ratio_vs_unloaded|queue_wait_p95_ms)"' \
        BENCH_hotpaths.json || true
fi
