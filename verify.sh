#!/usr/bin/env bash
# Tier-1 verify + lint + perf snapshot.
#
#   ./verify.sh          build + tests + clippy + hot-path bench (JSON)
#   ./verify.sh --quick  build + tests only
set -euo pipefail
cd "$(dirname "$0")/rust"

cargo build --release
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "clippy not installed; skipping lint"
fi

if [[ "${1:-}" != "--quick" ]]; then
    # regenerates rust/BENCH_hotpaths.json (the perf trajectory record:
    # VGG-layer single-thread vs stage-parallel, plan cold vs warm, and
    # fused vs staged pipelines with predicted DRAM traffic per mode)
    cargo bench --bench micro_hotpaths
    if [[ -f BENCH_hotpaths.json ]]; then
        echo "---- fused vs staged summary (BENCH_hotpaths.json) ----"
        grep -E '"(vgg|alexnet)_(staged_ms|fused_ms|fused_speedup|pred_staged_bytes|pred_fused_bytes|panel_tiles|exec_selected)"' \
            BENCH_hotpaths.json || true
    fi
fi
